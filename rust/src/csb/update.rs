//! Incremental [`HierCsb`] rebuild after a tree update: reuse the arena
//! regions of target leaves whose rows are unchanged, re-fill only the rest
//! — **bit-identical** to a from-scratch [`HierCsb::build_with_par`] over
//! the updated inputs.
//!
//! A target leaf is *reusable* when a per-row diff proves its block
//! contents would come out identical: same row lengths, bit-equal values,
//! and every column mapping to the same source leaf at the same span-local
//! offset.  The diff is self-contained evidence — the `clean`/`node_map`
//! flags from the tree update only pre-filter which leaves are worth
//! diffing — so reuse can never produce arenas that differ from a fresh
//! build, it can only conservatively fall back to re-filling.
//!
//! Everything that is a cheap pure function of the new inputs (traversal
//! order, exclusive scan, panel pack, stats) runs from scratch; the
//! expensive passes (count and fill, the only passes that scan the profile
//! matrix) are skipped per reused leaf.  A full-rebuild tree delta (all
//! leaves un-clean) degrades gracefully to exactly the from-scratch build.

use crate::csb::hier::{
    self, count_target_leaf, fill_target_leaf, BlockKind, HierCsb, LeafCount, Span,
};
use crate::obs::{self, counters, Counter};
use crate::par::pool::{SendPtr, ThreadPool};
use crate::sparse::csr::Csr;
use crate::tree::boxtree::BoxTree;
use crate::tree::update::TreeUpdate;

/// One side's (rows or columns) view of a tree update, in the form the CSB
/// reuse check consumes.
#[derive(Clone, Debug)]
pub struct SideDelta {
    /// New node id → old node id (`u32::MAX` = rebuilt).
    pub node_map: Vec<u32>,
    /// New node id → whole subtree preserved verbatim.
    pub clean: Vec<bool>,
    /// New tree position → old tree position (`u32::MAX` = inserted).
    pub pos_map: Vec<u32>,
}

impl SideDelta {
    /// Delta of an actual tree update.
    pub fn from_update(old_tree: &BoxTree, tu: &TreeUpdate) -> SideDelta {
        SideDelta {
            node_map: tu.node_map.clone(),
            clean: tu.clean.clone(),
            pos_map: tu.pos_map(old_tree),
        }
    }

    /// Delta of an unchanged side (e.g. a static source set while targets
    /// move): every node clean, every position its own image.
    pub fn identity(tree: &BoxTree) -> SideDelta {
        let nn = tree.nodes.len();
        SideDelta {
            node_map: (0..nn as u32).collect(),
            clean: vec![true; nn],
            pos_map: (0..tree.n() as u32).collect(),
        }
    }
}

/// Incremental rebuild of `old` for the updated profile `a_new` over the
/// updated trees.  `a_old` is the profile `old` was built from (needed for
/// the row diffs).  The result is bit-identical to
/// `HierCsb::build_with_par(a_new, new_tgt_tree, new_src_tree, block_cap,
/// old.dense_threshold, _)` at any thread count.
#[allow(clippy::too_many_arguments)]
pub fn update_par(
    old: &HierCsb,
    a_old: &Csr,
    a_new: &Csr,
    new_tgt_tree: &BoxTree,
    tdelta: &SideDelta,
    new_src_tree: &BoxTree,
    sdelta: &SideDelta,
    block_cap: usize,
    threads: usize,
) -> HierCsb {
    obs::span!("csb.update");
    assert_eq!(a_old.rows, old.rows, "a_old shape mismatch with old csb");
    assert_eq!(a_old.cols, old.cols, "a_old shape mismatch with old csb");
    assert_eq!(a_new.rows, new_tgt_tree.n());
    assert_eq!(a_new.cols, new_src_tree.n());
    assert_eq!(tdelta.pos_map.len(), a_new.rows, "target pos_map mismatch");
    assert_eq!(sdelta.pos_map.len(), a_new.cols, "source pos_map mismatch");
    let dense_threshold = old.dense_threshold;
    let block_cap = if block_cap == 0 { hier::LEAF_POINTS } else { block_cap };

    let tgt_leaf_ids = new_tgt_tree.cut_by_size(block_cap);
    let src_leaf_ids = new_src_tree.cut_by_size(block_cap);
    let tgt_leaves: Vec<Span> = tgt_leaf_ids
        .iter()
        .map(|&l| Span {
            lo: new_tgt_tree.nodes[l as usize].lo,
            hi: new_tgt_tree.nodes[l as usize].hi,
        })
        .collect();
    let src_leaves: Vec<Span> = src_leaf_ids
        .iter()
        .map(|&l| Span {
            lo: new_src_tree.nodes[l as usize].lo,
            hi: new_src_tree.nodes[l as usize].hi,
        })
        .collect();
    for sp in tgt_leaves.iter().chain(src_leaves.iter()) {
        assert!(
            sp.len() <= (u16::MAX as usize) + 1,
            "leaf span of {} points exceeds the u16 local-index range (block_cap {})",
            sp.len(),
            block_cap
        );
    }

    let col_leaf_new = hier::leaf_lookup(&src_leaves, a_new.cols);
    let col_leaf_old = hier::leaf_lookup(&old.src_leaves, a_old.cols);
    let pool = ThreadPool::new_or_default(threads);
    let nt = tgt_leaves.len();

    // Source cut correspondence: new source leaf ordinal → old ordinal when
    // the leaf's member rows sit in one preserved block (clean cut node and
    // an exactly matching old span), `u32::MAX` otherwise.  Leaf spans
    // partition the axis, so an exact span match identifies the unique old
    // leaf covering the same contiguous stretch of old positions.
    let find_old = |leaves: &[Span], old_lo: u32, len: usize| -> u32 {
        match leaves.binary_search_by_key(&old_lo, |s| s.lo) {
            Ok(o) if leaves[o].len() == len => o as u32,
            _ => u32::MAX,
        }
    };
    let src_old_ord: Vec<u32> = src_leaves
        .iter()
        .zip(&src_leaf_ids)
        .map(|(sp, &sn)| {
            if sp.is_empty() || !sdelta.clean[sn as usize] {
                return u32::MAX;
            }
            let old_lo = sdelta.pos_map[sp.lo as usize];
            if old_lo == u32::MAX {
                return u32::MAX;
            }
            find_old(&old.src_leaves, old_lo, sp.len())
        })
        .collect();
    let mut src_new_of_old = vec![u32::MAX; old.src_leaves.len()];
    for (sl, &so) in src_old_ord.iter().enumerate() {
        if so != u32::MAX {
            src_new_of_old[so as usize] = sl as u32;
        }
    }

    // Reuse plan: per new target leaf, the old target leaf whose arena
    // regions can be copied verbatim (`u32::MAX` = re-fill).  The per-row
    // diff below is the actual correctness proof; see module docs.
    let leaf_idx: Vec<usize> = (0..nt).collect();
    let plan: Vec<u32> = pool.map(&leaf_idx, |&tl| {
        let sp = tgt_leaves[tl];
        let tn = tgt_leaf_ids[tl] as usize;
        if sp.is_empty() || !tdelta.clean[tn] {
            return u32::MAX;
        }
        let old_lo = tdelta.pos_map[sp.lo as usize];
        if old_lo == u32::MAX {
            return u32::MAX;
        }
        let otl = find_old(&old.tgt_leaves, old_lo, sp.len());
        if otl == u32::MAX {
            return u32::MAX;
        }
        let osp = old.tgt_leaves[otl as usize];
        for t in 0..sp.len() as u32 {
            let (cn, vn) = a_new.row((sp.lo + t) as usize);
            let (co, vo) = a_old.row((osp.lo + t) as usize);
            if cn.len() != co.len() {
                return u32::MAX;
            }
            for e in 0..cn.len() {
                if vn[e].to_bits() != vo[e].to_bits() {
                    return u32::MAX;
                }
                let sl = col_leaf_new[cn[e] as usize];
                let so = src_old_ord[sl as usize];
                if so == u32::MAX || col_leaf_old[co[e] as usize] != so {
                    return u32::MAX;
                }
                if cn[e] - src_leaves[sl as usize].lo != co[e] - old.src_leaves[so as usize].lo {
                    return u32::MAX;
                }
            }
        }
        otl
    });

    // Count pass: reused leaves reconstruct their counts from the old block
    // metadata (the diff proved they are what a rescan would produce);
    // everything else rescans its rows.
    let count_span = obs::trace::SpanGuard::enter("csb.update.count");
    let per_leaf: Vec<Vec<LeafCount>> = pool.map(&leaf_idx, |&tl| {
        let otl = plan[tl];
        if otl == u32::MAX {
            return count_target_leaf(a_new, tgt_leaves[tl], &col_leaf_new);
        }
        let mut counts: Vec<LeafCount> = old.by_target[otl as usize]
            .iter()
            .map(|&bi| {
                let b = &old.blocks[bi as usize];
                let new_sl = src_new_of_old[b.sleaf as usize];
                debug_assert_ne!(new_sl, u32::MAX, "reused leaf references an unmapped source leaf");
                LeafCount {
                    sl: new_sl,
                    nnz: b.nnz,
                    // `rows` feeds only the Sparse arm of the scan; a block
                    // with identical nnz over an identical area keeps its
                    // storage kind, so the dense value is never read.
                    rows: match b.kind {
                        BlockKind::Sparse { row_cnt, .. } => row_cnt,
                        BlockKind::Dense { .. } => 0,
                    },
                    last_row: 0,
                }
            })
            .collect();
        counts.sort_unstable_by_key(|c| c.sl);
        counts
    });
    drop(count_span);

    // Traversal order + exclusive scan: cheap pure functions of the new
    // trees and counts — always fresh.
    let keys: Vec<(u32, u32)> = per_leaf
        .iter()
        .enumerate()
        .flat_map(|(tl, cs)| cs.iter().map(move |c| (tl as u32, c.sl)))
        .collect();
    let order = {
        obs::span!("csb.update.order");
        hier::multilevel_order(new_tgt_tree, new_src_tree, &tgt_leaf_ids, &src_leaf_ids, &keys)
    };
    assert_eq!(order.len(), keys.len(), "traversal missed blocks");
    let scan_span = obs::trace::SpanGuard::enter("csb.update.scan");
    let hier::Layout {
        blocks,
        ent_base,
        panel_off,
        panel_total,
        dense_len,
        rows_len,
        ptr_len,
        ents_len,
        by_target,
        lookup,
    } = hier::scan_layout(&order, &per_leaf, &tgt_leaves, &src_leaves, dense_threshold);
    drop(scan_span);

    // Fill pass: reused leaves copy their old arena regions (entry pointers
    // rebased to the new block bases), the rest re-scatter their rows.
    let fill_span = obs::trace::SpanGuard::enter("csb.update.fill");
    let mut dense = vec![0.0f32; dense_len];
    let mut sp_rows = vec![0u16; rows_len];
    let mut sp_ptr = vec![0u32; ptr_len];
    let mut sp_col = vec![0u16; ents_len];
    let mut sp_val = vec![0.0f32; ents_len];
    {
        let dp = SendPtr(dense.as_mut_ptr());
        let rp = SendPtr(sp_rows.as_mut_ptr());
        let pp = SendPtr(sp_ptr.as_mut_ptr());
        let cp = SendPtr(sp_col.as_mut_ptr());
        let vp = SendPtr(sp_val.as_mut_ptr());
        let (dpr, rpr, ppr, cpr, vpr) = (&dp, &rp, &pp, &cp, &vp);
        let blocks_ref = &blocks;
        let lookup_ref = &lookup;
        let ent_base_ref = &ent_base;
        let tgt_leaves_ref = &tgt_leaves;
        let col_leaf_ref = &col_leaf_new;
        let plan_ref = &plan;
        let src_old_ord_ref = &src_old_ord;
        pool.for_each_chunked(nt, 1, |tl| {
            // SAFETY: every write lands in an arena region of a block owned
            // by target leaf `tl`; block regions are disjoint.
            let dense_all: &mut [f32] = unsafe { std::slice::from_raw_parts_mut(dpr.0, dense_len) };
            let rows_all: &mut [u16] = unsafe { std::slice::from_raw_parts_mut(rpr.0, rows_len) };
            let ptr_all: &mut [u32] = unsafe { std::slice::from_raw_parts_mut(ppr.0, ptr_len) };
            let col_all: &mut [u16] = unsafe { std::slice::from_raw_parts_mut(cpr.0, ents_len) };
            let val_all: &mut [f32] = unsafe { std::slice::from_raw_parts_mut(vpr.0, ents_len) };
            let otl = plan_ref[tl];
            if otl == u32::MAX {
                fill_target_leaf(
                    a_new,
                    tgt_leaves_ref[tl],
                    &lookup_ref[tl],
                    col_leaf_ref,
                    blocks_ref,
                    ent_base_ref,
                    dense_all,
                    rows_all,
                    ptr_all,
                    col_all,
                    val_all,
                );
                return;
            }
            // Old (source leaf → block index) lookup for the reused leaf.
            let mut olst: Vec<(u32, u32)> = old.by_target[otl as usize]
                .iter()
                .map(|&bi| (old.blocks[bi as usize].sleaf, bi))
                .collect();
            olst.sort_unstable();
            for &(sl, bi) in &lookup_ref[tl] {
                let b = &blocks_ref[bi as usize];
                let old_sl = src_old_ord_ref[sl as usize];
                let obi = olst[olst
                    .binary_search_by_key(&old_sl, |e| e.0)
                    .expect("reused leaf lost a block")]
                .1 as usize;
                let ob = &old.blocks[obi];
                debug_assert_eq!(b.nnz, ob.nnz, "reused block nnz drifted");
                match (b.kind, ob.kind) {
                    (BlockKind::Dense { off }, BlockKind::Dense { off: ooff }) => {
                        let len = b.rows.len() * b.cols.len();
                        dense_all[off as usize..off as usize + len]
                            .copy_from_slice(&old.dense[ooff as usize..ooff as usize + len]);
                    }
                    (
                        BlockKind::Sparse {
                            row_off,
                            row_cnt,
                            ptr_off,
                        },
                        BlockKind::Sparse {
                            row_off: orow_off,
                            row_cnt: orow_cnt,
                            ptr_off: optr_off,
                        },
                    ) => {
                        debug_assert_eq!(row_cnt, orow_cnt, "reused block row count drifted");
                        rows_all[row_off as usize..(row_off + row_cnt) as usize].copy_from_slice(
                            &old.sp_rows[orow_off as usize..(orow_off + row_cnt) as usize],
                        );
                        // Entry pointers are absolute; rebase from the old
                        // block's entry base (= its ptr[0]) to the new one.
                        let obase = old.sp_ptr[optr_off as usize];
                        let nbase = ent_base_ref[bi as usize];
                        for t in 0..=row_cnt as usize {
                            ptr_all[ptr_off as usize + t] =
                                old.sp_ptr[optr_off as usize + t] - obase + nbase;
                        }
                        let nnz = b.nnz as usize;
                        col_all[nbase as usize..nbase as usize + nnz].copy_from_slice(
                            &old.sp_col[obase as usize..obase as usize + nnz],
                        );
                        val_all[nbase as usize..nbase as usize + nnz].copy_from_slice(
                            &old.sp_val[obase as usize..obase as usize + nnz],
                        );
                    }
                    _ => unreachable!(
                        "identical density and threshold must keep the block storage kind"
                    ),
                }
            }
        });
    }
    drop(fill_span);

    let reused = plan.iter().filter(|&&p| p != u32::MAX).count();
    counters::add(Counter::UpdateLeavesReused, reused as u64);
    counters::add(Counter::UpdateLeavesRebuilt, (nt - reused) as u64);

    // Pack + stats: pure functions of the new layout, always fresh.
    let pack_span = obs::trace::SpanGuard::enter("csb.update.pack");
    let panel_data = hier::pack_panels(&pool, &blocks, &panel_off, &dense, panel_total);
    drop(pack_span);
    let stats = hier::compute_stats(
        a_new.nnz(),
        a_new.rows,
        a_new.cols,
        &blocks,
        new_tgt_tree,
        &tgt_leaf_ids,
        panel_total,
    );
    stats.publish();

    HierCsb {
        rows: a_new.rows,
        cols: a_new.cols,
        nnz: a_new.nnz(),
        tgt_leaves,
        src_leaves,
        blocks,
        by_target,
        dense_threshold,
        dense,
        sp_rows,
        sp_ptr,
        sp_col,
        sp_val,
        panels: crate::csb::panel::PanelArena {
            off: panel_off,
            data: panel_data,
        },
        stats,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::Dataset;
    use crate::data::synth::SynthSpec;
    use crate::knn::exact::knn_graph;
    use crate::tree::update::{update_tree, UpdateBatch};
    use crate::util::rng::Rng;

    /// kNN profile of `ds` in `tree` order — the same recomputation both
    /// the incremental and the from-scratch side get.
    fn profile(ds: &Dataset, tree: &BoxTree) -> Csr {
        let dsr = ds.permuted(&tree.perm);
        let g = knn_graph(&dsr, 8, 2);
        Csr::from_knn(&g, dsr.n()).symmetrized()
    }

    /// Interior batch (away from the bbox hull) so the tree path stays
    /// incremental.
    fn interior_batch(ds: &Dataset, seed: u64, n_del: usize, n_ins: usize) -> UpdateBatch {
        let d = ds.d();
        let mut rng = Rng::new(seed);
        let mut lo = vec![f32::INFINITY; d];
        let mut hi = vec![f32::NEG_INFINITY; d];
        for i in 0..ds.n() {
            for (a, &x) in ds.row(i).iter().enumerate() {
                lo[a] = lo[a].min(x);
                hi[a] = hi[a].max(x);
            }
        }
        let on_hull = |row: &[f32]| row.iter().enumerate().any(|(a, &x)| x == lo[a] || x == hi[a]);
        let mut deletes = Vec::new();
        while deletes.len() < n_del {
            let i = rng.below(ds.n());
            if !on_hull(ds.row(i)) {
                deletes.push(i);
            }
        }
        let mut inserts = Vec::new();
        for _ in 0..n_ins {
            let i = rng.below(ds.n());
            for (a, &x) in ds.row(i).iter().enumerate() {
                inserts.push(0.9 * x + 0.1 * (0.5 * (lo[a] + hi[a])));
            }
        }
        UpdateBatch { deletes, inserts }
    }

    fn assert_csb_eq(want: &HierCsb, got: &HierCsb, what: &str) {
        assert_eq!(want.tgt_leaves, got.tgt_leaves, "{what}: tgt_leaves");
        assert_eq!(want.src_leaves, got.src_leaves, "{what}: src_leaves");
        assert_eq!(want.blocks, got.blocks, "{what}: block layout");
        assert_eq!(want.by_target, got.by_target, "{what}: by_target");
        assert_eq!(want.sp_rows, got.sp_rows, "{what}: sp_rows");
        assert_eq!(want.sp_ptr, got.sp_ptr, "{what}: sp_ptr");
        assert_eq!(want.sp_col, got.sp_col, "{what}: sp_col");
        assert_eq!(want.dense.len(), got.dense.len(), "{what}: dense len");
        assert!(
            want.dense.iter().zip(&got.dense).all(|(x, y)| x.to_bits() == y.to_bits()),
            "{what}: dense arena differs"
        );
        assert!(
            want.sp_val.iter().zip(&got.sp_val).all(|(x, y)| x.to_bits() == y.to_bits()),
            "{what}: sp_val arena differs"
        );
        assert_eq!(want.panels.off, got.panels.off, "{what}: panel offsets");
        let wp = want.panels.data.as_slice();
        let gp = got.panels.data.as_slice();
        assert_eq!(wp.len(), gp.len(), "{what}: panel arena len");
        assert!(
            wp.iter().zip(gp).all(|(x, y)| x.to_bits() == y.to_bits()),
            "{what}: panel arena differs"
        );
        assert_eq!(want.stats, got.stats, "{what}: stats");
    }

    #[test]
    fn update_bitidentical_with_fresh_build() {
        let ds = SynthSpec::blobs(500, 3, 4, 11).generate();
        let tree = BoxTree::build(&ds, 12, 24);
        let a_old = profile(&ds, &tree);
        let old = HierCsb::build_with_par(&a_old, &tree, &tree, 32, 0.5, 2);
        let batch = interior_batch(&ds, 41, 15, 15);
        let tu = update_tree(&tree, &ds, &batch, 24, 2);
        assert!(!tu.full_rebuild);
        let a_new = profile(&tu.ds, &tu.tree);
        let delta = SideDelta::from_update(&tree, &tu);
        let want = HierCsb::build_with_par(&a_new, &tu.tree, &tu.tree, 32, 0.5, 1);
        for threads in [1usize, 2, 8] {
            let before = counters::get(Counter::UpdateLeavesReused);
            let got = update_par(
                &old, &a_old, &a_new, &tu.tree, &delta, &tu.tree, &delta, 32, threads,
            );
            assert_csb_eq(&want, &got, &format!("threads={threads}"));
            // A localized batch on clustered data must actually reuse work.
            assert!(
                counters::get(Counter::UpdateLeavesReused) > before,
                "no leaves reused, threads={threads}"
            );
        }
    }

    #[test]
    fn chained_updates_stay_bitidentical() {
        let mut ds = SynthSpec::blobs(400, 2, 4, 19).generate();
        let mut tree = BoxTree::build(&ds, 12, 24);
        let mut a = profile(&ds, &tree);
        let mut csb = HierCsb::build_with_par(&a, &tree, &tree, 32, 0.5, 1);
        for step in 0..3u64 {
            let batch = interior_batch(&ds, 600 + step, 10, 10);
            let tu = update_tree(&tree, &ds, &batch, 24, 2);
            let a_new = profile(&tu.ds, &tu.tree);
            let delta = SideDelta::from_update(&tree, &tu);
            let got = update_par(
                &csb, &a, &a_new, &tu.tree, &delta, &tu.tree, &delta, 32, 2,
            );
            let want = HierCsb::build_with_par(&a_new, &tu.tree, &tu.tree, 32, 0.5, 1);
            assert_csb_eq(&want, &got, &format!("chain step {step}"));
            ds = tu.ds;
            tree = tu.tree;
            a = a_new;
            csb = got;
        }
    }

    #[test]
    fn full_rebuild_delta_degrades_to_fresh_build() {
        let ds = SynthSpec::blobs(300, 2, 3, 23).generate();
        let tree = BoxTree::build(&ds, 10, 24);
        let a_old = profile(&ds, &tree);
        let old = HierCsb::build_with_par(&a_old, &tree, &tree, 32, 0.5, 1);
        // hull-growing insert → full tree rebuild, nothing clean
        let batch = UpdateBatch {
            deletes: vec![],
            inserts: vec![1.0e3, -1.0e3],
        };
        let tu = update_tree(&tree, &ds, &batch, 24, 1);
        assert!(tu.full_rebuild);
        let a_new = profile(&tu.ds, &tu.tree);
        let delta = SideDelta::from_update(&tree, &tu);
        let got = update_par(
            &old, &a_old, &a_new, &tu.tree, &delta, &tu.tree, &delta, 32, 2,
        );
        let want = HierCsb::build_with_par(&a_new, &tu.tree, &tu.tree, 32, 0.5, 1);
        assert_csb_eq(&want, &got, "full-rebuild delta");
    }

    #[test]
    fn identity_delta_reuses_everything() {
        let ds = SynthSpec::blobs(350, 3, 3, 29).generate();
        let tree = BoxTree::build(&ds, 12, 24);
        let a = profile(&ds, &tree);
        let old = HierCsb::build_with_par(&a, &tree, &tree, 32, 0.5, 1);
        let delta = SideDelta::identity(&tree);
        let before = counters::get(Counter::UpdateLeavesReused);
        let got = update_par(&old, &a, &a, &tree, &delta, &tree, &delta, 32, 2);
        assert_csb_eq(&old, &got, "identity delta");
        // Counters are global and other tests add to them concurrently, so
        // only the lower bound of this call's own contribution is checked.
        assert!(
            counters::get(Counter::UpdateLeavesReused) - before >= old.tgt_leaves.len() as u64,
            "identity delta re-filled a leaf"
        );
    }
}
