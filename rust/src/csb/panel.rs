//! Packed dense-block panels: the value layout the SIMD dense micro-kernel
//! consumes.
//!
//! A dense block's row-major values answer one question badly: "give me
//! the next `PANEL_MR` rows' values at reduction column `c`" — the loads
//! stride by the block width.  Packing at `HierCsb` build time rearranges
//! each dense block into **tile-major panels**: rows are grouped into
//! tiles of [`PANEL_MR`], and within a tile the values are stored
//! column-major (`panel[tile*cols*MR + c*MR + r']`), so every reduction
//! step of the micro-kernel loads `PANEL_MR` consecutive values.  Tail
//! rows are zero-padded inside the tile (the kernel computes them but
//! never stores them), and every panel starts 32-byte aligned
//! ([`AlignedF32`] + the 8-float rounding in [`panel_len`]) so streaming
//! reads stay cache-line resident.
//!
//! The row-major `dense` arena is kept alongside: it is the layout the
//! fused engines materialize per-iteration weights in, the coordinator's
//! PJRT packing reads, and the scalar reference kernel consumes — the
//! panel arena costs one extra copy of the dense values (< the index
//! arenas saved by `u16` DCSR columns on typical profiles) and buys the
//! SIMD kernel contiguous loads on the stationary hot path.

/// Rows per panel tile: 4 broadcast-FMA accumulators per reduction step
/// (4 ymm accumulators + 1 RHS vector leaves the AVX2 register file room
/// for the broadcasts).
pub const PANEL_MR: usize = 4;

/// Sentinel panel offset for blocks without a panel (sparse-stored).
pub const NO_PANEL: u32 = u32::MAX;

/// Panel footprint in f32 of an `rn x cn` dense block: full tiles of
/// [`PANEL_MR`] rows, rounded to 8 floats so the *next* panel stays
/// 32-byte aligned.
pub fn panel_len(rn: usize, cn: usize) -> usize {
    (rn.div_ceil(PANEL_MR) * cn * PANEL_MR).next_multiple_of(8)
}

/// Pack a row-major `rn x cn` block into tile-major panels (see module
/// docs).  `out` must be zeroed and at least [`panel_len`] long — pad rows
/// and the alignment tail stay zero.
pub fn pack_panel(d: &[f32], rn: usize, cn: usize, out: &mut [f32]) {
    debug_assert!(d.len() >= rn * cn);
    debug_assert!(out.len() >= rn.div_ceil(PANEL_MR) * cn * PANEL_MR);
    for r in 0..rn {
        let base = (r / PANEL_MR) * cn * PANEL_MR + (r % PANEL_MR);
        let row = &d[r * cn..(r + 1) * cn];
        for (c, &v) in row.iter().enumerate() {
            out[base + c * PANEL_MR] = v;
        }
    }
}

/// 32-byte block underlying [`AlignedF32`] (8 f32 = one AVX2 register).
#[repr(C, align(32))]
#[derive(Clone, Copy, Debug, Default, PartialEq)]
struct Chunk([f32; 8]);

const ZERO_CHUNK: Chunk = Chunk([0.0; 8]);

/// A 32-byte-aligned `f32` buffer (a `Vec<f32>` only guarantees 4-byte
/// alignment).  Exposes plain slices; the chunked backing store is an
/// implementation detail.
#[derive(Clone, Default, PartialEq)]
pub struct AlignedF32 {
    buf: Vec<Chunk>,
    len: usize,
}

impl AlignedF32 {
    /// A zero-initialized buffer of `len` floats.
    pub fn zeroed(len: usize) -> AlignedF32 {
        AlignedF32 {
            buf: vec![ZERO_CHUNK; len.div_ceil(8)],
            len,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn as_slice(&self) -> &[f32] {
        // SAFETY: `buf` stores `len.div_ceil(8)` contiguous `Chunk`s
        // (size 32, align 32 — no padding between elements), i.e. at least
        // `len` contiguous, initialized f32 at 32-byte-aligned storage.
        unsafe { std::slice::from_raw_parts(self.buf.as_ptr().cast::<f32>(), self.len) }
    }

    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        // SAFETY: as in `as_slice`, plus exclusive access via `&mut self`.
        unsafe { std::slice::from_raw_parts_mut(self.buf.as_mut_ptr().cast::<f32>(), self.len) }
    }

    /// Set the length to `len` with all floats zeroed, reusing capacity —
    /// the per-apply scratch pattern (allocation-free once the high-water
    /// mark is reached).  Returns the buffer as a slice.
    pub fn reset_zeroed(&mut self, len: usize) -> &mut [f32] {
        let chunks = len.div_ceil(8);
        if self.buf.len() < chunks {
            self.buf.resize(chunks, ZERO_CHUNK);
        }
        for c in &mut self.buf[..chunks] {
            *c = ZERO_CHUNK;
        }
        self.len = len;
        self.as_mut_slice()
    }
}

impl std::fmt::Debug for AlignedF32 {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "AlignedF32(len={})", self.len)
    }
}

/// Per-block panel directory + the shared aligned value arena, built once
/// by `HierCsb::build_with_par` (deterministically: each block's panel is
/// a pure function of its dense values).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct PanelArena {
    /// Per block (same indexing as `HierCsb::blocks`): offset of the
    /// block's panel in `data`, or [`NO_PANEL`] for sparse blocks.
    pub off: Vec<u32>,
    pub data: AlignedF32,
}

impl PanelArena {
    /// The packed panel of block `t` (`None` for sparse-stored blocks).
    /// `rn`/`cn` are the block's span lengths.
    pub fn panel(&self, t: usize, rn: usize, cn: usize) -> Option<&[f32]> {
        let off = self.off[t];
        if off == NO_PANEL {
            return None;
        }
        let off = off as usize;
        Some(&self.data.as_slice()[off..off + panel_len(rn, cn)])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn aligned_buffer_is_32_byte_aligned_and_zeroed() {
        for len in [0usize, 1, 7, 8, 9, 31, 200] {
            let mut a = AlignedF32::zeroed(len);
            assert_eq!(a.len(), len);
            assert_eq!(a.as_slice().as_ptr() as usize % 32, 0);
            assert!(a.as_slice().iter().all(|&v| v == 0.0));
            a.as_mut_slice().iter_mut().for_each(|v| *v = 1.0);
            // reuse resets to zero without losing alignment
            let s = a.reset_zeroed(len);
            assert!(s.iter().all(|&v| v == 0.0));
            assert_eq!(s.as_ptr() as usize % 32, 0);
        }
    }

    #[test]
    fn panel_roundtrips_rowmajor_values() {
        let mut rng = Rng::new(5);
        for &(rn, cn) in &[(1usize, 1usize), (3, 5), (4, 4), (5, 9), (16, 3), (13, 31)] {
            let d: Vec<f32> = (0..rn * cn).map(|_| rng.f32()).collect();
            let mut p = vec![0.0f32; panel_len(rn, cn)];
            pack_panel(&d, rn, cn, &mut p);
            for r in 0..rn {
                for c in 0..cn {
                    let got = p[(r / PANEL_MR) * cn * PANEL_MR + c * PANEL_MR + (r % PANEL_MR)];
                    assert_eq!(got.to_bits(), d[r * cn + c].to_bits(), "({rn}x{cn}) at ({r},{c})");
                }
            }
            // pad rows in the tail tile stay zero
            let tiles = rn.div_ceil(PANEL_MR);
            for r in rn..tiles * PANEL_MR {
                for c in 0..cn {
                    let got = p[(r / PANEL_MR) * cn * PANEL_MR + c * PANEL_MR + (r % PANEL_MR)];
                    assert_eq!(got, 0.0);
                }
            }
        }
    }

    #[test]
    fn panel_len_is_aligned() {
        for &(rn, cn) in &[(1usize, 1usize), (3, 5), (4, 8), (129, 17)] {
            assert_eq!(panel_len(rn, cn) % 8, 0);
            assert!(panel_len(rn, cn) >= rn.div_ceil(PANEL_MR) * cn * PANEL_MR);
        }
    }
}
