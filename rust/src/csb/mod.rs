//! Multi-level compressed sparse block storage (§2.4) — the paper's
//! generalization of Buluç et al.'s CSB to *adaptive* blocks derived from
//! the data's cluster hierarchy, plus the matching hierarchical vector
//! layout.

pub mod hier;
pub mod layout;
