//! Multi-level compressed sparse block storage (§2.4) — the paper's
//! generalization of Buluç et al.'s CSB to *adaptive* blocks derived from
//! the data's cluster hierarchy, plus the matching hierarchical vector
//! layout and the apply-side execution layer: packed dense-block panels
//! ([`panel`]) and runtime-dispatched micro-kernels ([`kernel`]).

pub mod hier;
pub mod kernel;
pub mod layout;
pub mod panel;
pub mod update;
