//! Hierarchical compressed sparse blocks.
//!
//! Leaf clusters of the target tree block the rows; leaf clusters of the
//! source tree block the columns.  Every nonzero lands in exactly one
//! (target-leaf × source-leaf) block; blocks denser than a threshold are
//! stored *dense* (the granule shipped to the PJRT block kernels), the rest
//! as local CSR with 16-bit local column indices.
//!
//! Two traversal schedules are materialized:
//!
//! * **multi-level** — the recursive dual-tree descent order: a parent
//!   cluster pair's blocks are completed before moving on, so both the
//!   charge segment and the potential segment being touched stay resident
//!   across consecutive blocks (the paper's "interaction is calculated at
//!   multiple levels");
//! * **flat** — row-major over (target leaf, source leaf), i.e. classic
//!   single-level CSB; kept for the ablation benches.

use crate::csb::kernel::{self, Dispatch};
use crate::csb::panel::{self, PanelArena};
use crate::obs::{self, counters, Counter, LevelStat};
use crate::par::pool::{SendPtr, ThreadPool};
use crate::sparse::csr::Csr;
use crate::tree::boxtree::BoxTree;
use std::collections::HashMap;

// The micro-kernel layer moved to `csb::kernel`; re-exported here because
// the granule was born in this module and callers import it from here.
pub use crate::csb::kernel::{dense_gemm_acc, GEMM_KC};

/// Half-open index span.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Span {
    pub lo: u32,
    pub hi: u32,
}

impl Span {
    #[inline]
    pub fn len(&self) -> usize {
        (self.hi - self.lo) as usize
    }
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.hi == self.lo
    }
}

/// Block payload locator into the [`HierCsb`] arenas.
///
/// All block values live in four shared arenas (one allocation each), not
/// per-block `Vec`s: iterating blocks in traversal order then walks memory
/// *linearly*, which is the whole point of the reordering exercise — the
/// perf pass measured ~240 ns/block of pointer-chasing overhead with
/// per-block allocations (repo-root `EXPERIMENTS.md` §Perf).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BlockKind {
    /// Row-major `rows.len() x cols.len()` values at `dense[off..]`.
    Dense { off: u32 },
    /// Doubly-compressed local CSR (DCSR): `row_cnt` *occupied* local rows
    /// at `sp_rows[row_off..]`, with entries
    /// `sp_col/sp_val[sp_ptr[ptr_off+t]..sp_ptr[ptr_off+t+1]]` — empty rows
    /// in the span cost nothing.
    Sparse {
        row_off: u32,
        row_cnt: u32,
        ptr_off: u32,
    },
}

/// One (target leaf × source leaf) block (metadata; payload in the arenas).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LeafBlock {
    /// Target (row) leaf ordinal and source (column) leaf ordinal.
    pub tleaf: u32,
    pub sleaf: u32,
    pub rows: Span,
    pub cols: Span,
    pub nnz: u32,
    pub kind: BlockKind,
}

impl LeafBlock {
    /// Density of the block.
    pub fn density(&self) -> f64 {
        self.nnz as f64 / (self.rows.len() as f64 * self.cols.len() as f64)
    }

    pub fn is_dense(&self) -> bool {
        matches!(self.kind, BlockKind::Dense { .. })
    }
}

/// The hierarchical CSB matrix.
#[derive(Clone, Debug)]
pub struct HierCsb {
    pub rows: usize,
    pub cols: usize,
    pub nnz: usize,
    /// Row blocking: target-leaf spans in order.
    pub tgt_leaves: Vec<Span>,
    /// Column blocking: source-leaf spans in order.
    pub src_leaves: Vec<Span>,
    /// Leaf blocks, stored in **multi-level traversal order**.
    pub blocks: Vec<LeafBlock>,
    /// Per target leaf: indices into `blocks` (ascending source leaf).
    pub by_target: Vec<Vec<u32>>,
    /// Dense-storage density threshold used at build time.
    pub dense_threshold: f64,
    /// Dense-block value arena (row-major per block).
    pub dense: Vec<f32>,
    /// DCSR arenas: occupied local rows, absolute entry pointers, local
    /// columns, values.
    pub sp_rows: Vec<u16>,
    pub sp_ptr: Vec<u32>,
    pub sp_col: Vec<u16>,
    pub sp_val: Vec<f32>,
    /// Tile-major packed copies of the dense blocks (32-byte aligned), the
    /// layout the SIMD dense micro-kernel consumes.
    pub panels: PanelArena,
    /// Profile statistics computed once at build and published to the
    /// `obs` counter registry — `describe()`, the `reorder` CLI report,
    /// and bench records all read this one set of numbers.
    pub stats: CsbStats,
}

/// Build-time profile statistics of a [`HierCsb`] (the paper's profile
/// measure at the storage layer).  Published to `obs::counters` by
/// [`CsbStats::publish`]; levels are target-leaf depths in the ordering
/// tree.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CsbStats {
    pub dense_blocks: u64,
    pub sparse_blocks: u64,
    /// Σ rows·cols over dense-stored blocks.
    pub dense_cells: u64,
    /// Nonzeros living in dense-stored blocks.
    pub dense_nnz: u64,
    /// Total stored nonzeros.
    pub nnz: u64,
    /// Σ rows·cols over all stored blocks (the near-field footprint).
    pub covered_area: u64,
    /// rows·cols of the whole matrix.
    pub total_area: u64,
    /// Bytes of the packed panel arena (dense-block SIMD copies).
    pub panel_bytes: u64,
    /// Per target-leaf-depth rows, ascending level, empty levels omitted.
    pub levels: Vec<CsbLevelStats>,
}

/// One level row of [`CsbStats`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CsbLevelStats {
    pub level: u32,
    pub blocks: u64,
    pub dense_blocks: u64,
    pub nnz: u64,
    pub cells: u64,
}

impl CsbStats {
    /// Fraction of nonzeros living in dense-stored blocks.
    pub fn dense_fraction(&self) -> f64 {
        self.dense_nnz as f64 / self.nnz.max(1) as f64
    }

    /// `covered_area / total_area` (0 for an empty matrix).
    pub fn covered_fraction(&self) -> f64 {
        self.covered_area as f64 / self.total_area.max(1) as f64
    }

    /// Fold this build's numbers into the global `obs` counter registry.
    pub fn publish(&self) {
        counters::add(Counter::CsbDenseBlocks, self.dense_blocks);
        counters::add(Counter::CsbSparseBlocks, self.sparse_blocks);
        counters::add(Counter::CsbDenseCells, self.dense_cells);
        counters::add(Counter::CsbDenseNnz, self.dense_nnz);
        counters::add(Counter::CsbNnz, self.nnz);
        counters::add(Counter::CsbCoveredArea, self.covered_area);
        counters::add(Counter::CsbTotalArea, self.total_area);
        counters::add(Counter::CsbPanelBytes, self.panel_bytes);
        for l in &self.levels {
            counters::level_add(LevelStat::Blocks, l.level as usize, l.blocks);
            counters::level_add(LevelStat::DenseBlocks, l.level as usize, l.dense_blocks);
            counters::level_add(LevelStat::Nnz, l.level as usize, l.nnz);
            counters::level_add(LevelStat::Cells, l.level as usize, l.cells);
        }
    }
}

/// Default leaf population cap used across the system (matches the m256
/// AOT artifact tile).
pub const LEAF_POINTS: usize = 256;

impl HierCsb {
    /// Build from a matrix already reordered by the two trees.
    ///
    /// `a` must be `A(π_t, π_s)` where π_t/π_s are the trees' permutations;
    /// row/column spans of the tree's nodes are then contiguous index
    /// ranges.  `block_cap` sets the blocking granularity via a size-based
    /// tree cut — the ordering tree itself may be much deeper (fine-grained
    /// locality) while blocks stay ~block_cap points (artifact tile size).
    pub fn build(a: &Csr, tgt_tree: &BoxTree, src_tree: &BoxTree, block_cap: usize) -> HierCsb {
        // 0.6 default: a dense block must be ≥60% populated so the dense
        // matvec's wasted flops stay bounded by 1.67x (perf pass, DESIGN §8).
        Self::build_with(a, tgt_tree, src_tree, block_cap, 0.6)
    }

    pub fn build_with(
        a: &Csr,
        tgt_tree: &BoxTree,
        src_tree: &BoxTree,
        block_cap: usize,
        dense_threshold: f64,
    ) -> HierCsb {
        Self::build_with_par(a, tgt_tree, src_tree, block_cap, dense_threshold, 1)
    }

    /// Parallel build with the default dense threshold (`threads = 0` means
    /// the machine default).
    pub fn build_par(
        a: &Csr,
        tgt_tree: &BoxTree,
        src_tree: &BoxTree,
        block_cap: usize,
        threads: usize,
    ) -> HierCsb {
        Self::build_with_par(a, tgt_tree, src_tree, block_cap, 0.6, threads)
    }

    /// The assembly proper: count → exclusive scan → parallel fill into the
    /// four shared arenas.  Every arena region belongs to exactly one block
    /// and every block to exactly one **target leaf** (the same ownership
    /// discipline as `spmv::multilevel::spmm_ml_par`), so target leaves fill
    /// concurrently with no synchronization — and because each block is
    /// filled by one leaf's fixed row scan, the result is **bit-identical**
    /// across thread counts.
    pub fn build_with_par(
        a: &Csr,
        tgt_tree: &BoxTree,
        src_tree: &BoxTree,
        block_cap: usize,
        dense_threshold: f64,
        threads: usize,
    ) -> HierCsb {
        obs::span!("csb.build");
        assert_eq!(a.rows, tgt_tree.n());
        assert_eq!(a.cols, src_tree.n());
        let block_cap = if block_cap == 0 { LEAF_POINTS } else { block_cap };
        let tgt_leaf_ids = tgt_tree.cut_by_size(block_cap);
        let src_leaf_ids = src_tree.cut_by_size(block_cap);
        let tgt_leaves: Vec<Span> = tgt_leaf_ids
            .iter()
            .map(|&l| Span {
                lo: tgt_tree.nodes[l as usize].lo,
                hi: tgt_tree.nodes[l as usize].hi,
            })
            .collect();
        let src_leaves: Vec<Span> = src_leaf_ids
            .iter()
            .map(|&l| Span {
                lo: src_tree.nodes[l as usize].lo,
                hi: src_tree.nodes[l as usize].hi,
            })
            .collect();

        // The DCSR arenas index local rows/columns with u16: a leaf span is
        // bounded by the size cut at ~block_cap points, but an unsplittable
        // leaf (duplicates past the tree's depth cap) can exceed it, so the
        // bound is asserted rather than assumed.
        for sp in tgt_leaves.iter().chain(src_leaves.iter()) {
            assert!(
                sp.len() <= (u16::MAX as usize) + 1,
                "leaf span of {} points exceeds the u16 local-index range (block_cap {})",
                sp.len(),
                block_cap
            );
        }

        // Map col -> source leaf ordinal (rows are scanned per target leaf).
        let col_leaf = leaf_lookup(&src_leaves, a.cols);
        let pool = ThreadPool::new_or_default(threads);
        let nt = tgt_leaves.len();

        // Pass 1 — count (parallel over target leaves): the occupied source
        // leaves of each target leaf, with per-block nnz and occupied-row
        // counts.  Counts depend only on the leaf's own rows, so the result
        // is thread-count independent.
        let leaf_idx: Vec<usize> = (0..nt).collect();
        let count_span = obs::trace::SpanGuard::enter("csb.build.count");
        let per_leaf: Vec<Vec<LeafCount>> =
            pool.map(&leaf_idx, |&tl| count_target_leaf(a, tgt_leaves[tl], &col_leaf));

        drop(count_span);

        // Block keys, ordered by the multi-level traversal.
        let keys: Vec<(u32, u32)> = per_leaf
            .iter()
            .enumerate()
            .flat_map(|(tl, cs)| cs.iter().map(move |c| (tl as u32, c.sl)))
            .collect();
        let order = {
            obs::span!("csb.build.order");
            multilevel_order(tgt_tree, src_tree, &tgt_leaf_ids, &src_leaf_ids, &keys)
        };
        assert_eq!(order.len(), keys.len(), "traversal missed blocks");

        // Exclusive scan — arena offsets in traversal order, so the hot
        // loop walks memory linearly.
        let scan_span = obs::trace::SpanGuard::enter("csb.build.scan");
        let Layout {
            blocks,
            ent_base,
            panel_off,
            panel_total,
            dense_len,
            rows_len,
            ptr_len,
            ents_len,
            by_target,
            lookup,
        } = scan_layout(&order, &per_leaf, &tgt_leaves, &src_leaves, dense_threshold);
        drop(scan_span);

        // Pass 2 — fill (parallel over target leaves).
        let fill_span = obs::trace::SpanGuard::enter("csb.build.fill");
        let mut dense = vec![0.0f32; dense_len];
        let mut sp_rows = vec![0u16; rows_len];
        let mut sp_ptr = vec![0u32; ptr_len];
        let mut sp_col = vec![0u16; ents_len];
        let mut sp_val = vec![0.0f32; ents_len];
        {
            let dp = SendPtr(dense.as_mut_ptr());
            let rp = SendPtr(sp_rows.as_mut_ptr());
            let pp = SendPtr(sp_ptr.as_mut_ptr());
            let cp = SendPtr(sp_col.as_mut_ptr());
            let vp = SendPtr(sp_val.as_mut_ptr());
            let (dpr, rpr, ppr, cpr, vpr) = (&dp, &rp, &pp, &cp, &vp);
            let blocks_ref = &blocks;
            let lookup_ref = &lookup;
            let ent_base_ref = &ent_base;
            let tgt_leaves_ref = &tgt_leaves;
            let col_leaf_ref = &col_leaf;
            pool.for_each_chunked(nt, 1, |tl| {
                // SAFETY: every write lands in an arena region of a block
                // owned by target leaf `tl`; block regions are disjoint.
                let dense_all: &mut [f32] =
                    unsafe { std::slice::from_raw_parts_mut(dpr.0, dense_len) };
                let rows_all: &mut [u16] =
                    unsafe { std::slice::from_raw_parts_mut(rpr.0, rows_len) };
                let ptr_all: &mut [u32] =
                    unsafe { std::slice::from_raw_parts_mut(ppr.0, ptr_len) };
                let col_all: &mut [u16] =
                    unsafe { std::slice::from_raw_parts_mut(cpr.0, ents_len) };
                let val_all: &mut [f32] =
                    unsafe { std::slice::from_raw_parts_mut(vpr.0, ents_len) };
                fill_target_leaf(
                    a,
                    tgt_leaves_ref[tl],
                    &lookup_ref[tl],
                    col_leaf_ref,
                    blocks_ref,
                    ent_base_ref,
                    dense_all,
                    rows_all,
                    ptr_all,
                    col_all,
                    val_all,
                );
            });
        }

        drop(fill_span);

        // Pass 3 — pack each dense block's values into its tile-major
        // panel (parallel over blocks; every panel region belongs to
        // exactly one block and each pack is a pure function of that
        // block's dense values, so the arena is bit-identical across
        // thread counts).
        let pack_span = obs::trace::SpanGuard::enter("csb.build.pack");
        let panel_data = pack_panels(&pool, &blocks, &panel_off, &dense, panel_total);
        drop(pack_span);

        // Profile stats — computed once, published to the global counter
        // registry, and stored so describe()/reports never recompute.
        let stats = compute_stats(
            a.nnz(),
            a.rows,
            a.cols,
            &blocks,
            tgt_tree,
            &tgt_leaf_ids,
            panel_total,
        );
        stats.publish();

        HierCsb {
            rows: a.rows,
            cols: a.cols,
            nnz: a.nnz(),
            tgt_leaves,
            src_leaves,
            blocks,
            by_target,
            dense_threshold,
            dense,
            sp_rows,
            sp_ptr,
            sp_col,
            sp_val,
            panels: PanelArena {
                off: panel_off,
                data: panel_data,
            },
            stats,
        }
    }

    /// One block's `y[rows] += B · x[cols]` over the arenas.
    #[inline]
    pub fn block_matvec(&self, t: usize, x: &[f32], y: &mut [f32]) {
        let b = &self.blocks[t];
        let x_seg = &x[b.cols.lo as usize..b.cols.hi as usize];
        let y_seg = &mut y[b.rows.lo as usize..b.rows.hi as usize];
        match b.kind {
            BlockKind::Dense { off } => {
                let w = b.cols.len();
                let d = &self.dense[off as usize..off as usize + b.rows.len() * w];
                for (r, yv) in y_seg.iter_mut().enumerate() {
                    let row = &d[r * w..(r + 1) * w];
                    let mut acc = 0.0f32;
                    for (rv, xv) in row.iter().zip(x_seg) {
                        acc += rv * xv;
                    }
                    *yv += acc;
                }
            }
            BlockKind::Sparse {
                row_off,
                row_cnt,
                ptr_off,
            } => {
                let rows = &self.sp_rows[row_off as usize..(row_off + row_cnt) as usize];
                let ptr = &self.sp_ptr[ptr_off as usize..(ptr_off + row_cnt + 1) as usize];
                for (t, &r) in rows.iter().enumerate() {
                    let lo = ptr[t] as usize;
                    let hi = ptr[t + 1] as usize;
                    let mut acc = 0.0f32;
                    for e in lo..hi {
                        acc += self.sp_val[e] * x_seg[self.sp_col[e] as usize];
                    }
                    y_seg[r as usize] += acc;
                }
            }
        }
    }

    /// One block's multi-RHS update `Y[rows] += B · X[cols]` over the
    /// arenas, with `X`/`Y` stored row-major `n x k` (RHS index fastest —
    /// the same layout the engine uses for `n x d` coordinate arrays).
    ///
    /// Dense blocks run the register-blocked micro-GEMM
    /// ([`dense_gemm_acc`]); DCSR blocks run row-wise k-wide AXPYs.  For
    /// every RHS column the per-output accumulation chain is identical to
    /// [`Self::block_matvec`]'s, so `block_matmul(k=1)` is **bit-exact**
    /// with the scalar path (rustc does not reassociate float ops).
    #[inline]
    pub fn block_matmul(&self, t: usize, x: &[f32], y: &mut [f32], k: usize) {
        let b = &self.blocks[t];
        let y_seg = &mut y[b.rows.lo as usize * k..b.rows.hi as usize * k];
        self.block_matmul_seg(t, x, y_seg, k);
    }

    /// [`Self::block_matmul`] into the block's already-sliced output row
    /// segment (`block_rows x k`) — the form the parallel drivers use so a
    /// task only ever holds a mutable slice of its own leaf's rows (blocks
    /// span exactly one target leaf).
    #[inline]
    pub fn block_matmul_seg(&self, t: usize, x: &[f32], y_seg: &mut [f32], k: usize) {
        let b = &self.blocks[t];
        debug_assert_eq!(y_seg.len(), b.rows.len() * k);
        let x_seg = &x[b.cols.lo as usize * k..b.cols.hi as usize * k];
        match b.kind {
            BlockKind::Dense { off } => {
                let w = b.cols.len();
                let d = &self.dense[off as usize..off as usize + b.rows.len() * w];
                dense_gemm_acc(d, b.rows.len(), w, x_seg, k, y_seg);
            }
            BlockKind::Sparse {
                row_off,
                row_cnt,
                ptr_off,
            } => {
                let rows = &self.sp_rows[row_off as usize..(row_off + row_cnt) as usize];
                let ptr = &self.sp_ptr[ptr_off as usize..(ptr_off + row_cnt + 1) as usize];
                kernel::dcsr_gemm_acc(rows, ptr, &self.sp_col, &self.sp_val, x_seg, k, y_seg);
            }
        }
    }

    /// [`Self::block_matmul`] under an explicit kernel dispatch: `Scalar`
    /// is the golden reference above; `Avx2` runs the SIMD micro-kernels
    /// over the packed panel (dense) / the DCSR arenas (sparse).
    #[inline]
    pub fn block_matmul_with(&self, t: usize, x: &[f32], y: &mut [f32], k: usize, d: Dispatch) {
        let b = &self.blocks[t];
        let y_seg = &mut y[b.rows.lo as usize * k..b.rows.hi as usize * k];
        self.block_matmul_seg_with(t, x, y_seg, k, d);
    }

    /// [`Self::block_matmul_seg`] under an explicit kernel dispatch.
    #[inline]
    pub fn block_matmul_seg_with(
        &self,
        t: usize,
        x: &[f32],
        y_seg: &mut [f32],
        k: usize,
        d: Dispatch,
    ) {
        match d {
            Dispatch::Scalar => self.block_matmul_seg(t, x, y_seg, k),
            Dispatch::Avx2 => self.block_matmul_seg_avx2(t, x, y_seg, k),
        }
    }

    #[cfg(target_arch = "x86_64")]
    fn block_matmul_seg_avx2(&self, t: usize, x: &[f32], y_seg: &mut [f32], k: usize) {
        // Re-verify CPU support so a hand-built Dispatch::Avx2 from safe
        // code cannot reach the target-feature kernels on an unsupported
        // CPU (std caches the feature probe — one relaxed atomic load).
        if kernel::detect() != Dispatch::Avx2 {
            return self.block_matmul_seg(t, x, y_seg, k);
        }
        let b = &self.blocks[t];
        debug_assert_eq!(y_seg.len(), b.rows.len() * k);
        let x_seg = &x[b.cols.lo as usize * k..b.cols.hi as usize * k];
        match b.kind {
            BlockKind::Dense { .. } => {
                let (rn, cn) = (b.rows.len(), b.cols.len());
                let p = self
                    .panels
                    .panel(t, rn, cn)
                    .expect("dense block without a packed panel");
                // SAFETY: the detect() guard above confirmed AVX2+FMA.
                unsafe { kernel::avx2::panel_gemm_acc(p, rn, cn, x_seg, k, y_seg) };
            }
            BlockKind::Sparse {
                row_off,
                row_cnt,
                ptr_off,
            } => {
                let rows = &self.sp_rows[row_off as usize..(row_off + row_cnt) as usize];
                let ptr = &self.sp_ptr[ptr_off as usize..(ptr_off + row_cnt + 1) as usize];
                // SAFETY: the detect() guard above confirmed AVX2+FMA.
                unsafe {
                    kernel::avx2::dcsr_gemm_acc(
                        rows,
                        ptr,
                        &self.sp_col,
                        &self.sp_val,
                        x_seg,
                        k,
                        y_seg,
                    )
                };
            }
        }
    }

    #[cfg(not(target_arch = "x86_64"))]
    fn block_matmul_seg_avx2(&self, t: usize, x: &[f32], y_seg: &mut [f32], k: usize) {
        // No SIMD kernel on this target; `kernel::detect()` never yields
        // Avx2 here, this arm only backstops a hand-built Dispatch.
        self.block_matmul_seg(t, x, y_seg, k)
    }

    /// Sequential multi-level SpMM: `Y = A X` with `k` RHS columns
    /// (`x`: `cols x k`, `y`: `rows x k`, both row-major; y overwritten).
    pub fn spmm(&self, x: &[f32], y: &mut [f32], k: usize) {
        assert!(k >= 1, "spmm needs at least one RHS column");
        assert_eq!(x.len(), self.cols * k);
        assert_eq!(y.len(), self.rows * k);
        y.fill(0.0);
        for t in 0..self.blocks.len() {
            self.block_matmul(t, x, y, k);
        }
    }

    /// Sequential SpMM in an explicit block order (ablation hook).
    pub fn spmm_ordered(&self, order: &[u32], x: &[f32], y: &mut [f32], k: usize) {
        assert!(k >= 1, "spmm needs at least one RHS column");
        assert_eq!(x.len(), self.cols * k);
        assert_eq!(y.len(), self.rows * k);
        y.fill(0.0);
        for &t in order {
            self.block_matmul(t as usize, x, y, k);
        }
    }

    /// Visit every stored nonzero of block `t` as (local_row, local_col,
    /// value).
    #[inline]
    pub fn for_each_nz<F: FnMut(usize, usize, f32)>(&self, t: usize, mut f: F) {
        let b = &self.blocks[t];
        match b.kind {
            BlockKind::Dense { off } => {
                let w = b.cols.len();
                for r in 0..b.rows.len() {
                    let row = &self.dense[off as usize + r * w..off as usize + (r + 1) * w];
                    for (c, &v) in row.iter().enumerate() {
                        if v != 0.0 {
                            f(r, c, v);
                        }
                    }
                }
            }
            BlockKind::Sparse {
                row_off,
                row_cnt,
                ptr_off,
            } => {
                for t in 0..row_cnt as usize {
                    let r = self.sp_rows[row_off as usize + t] as usize;
                    let lo = self.sp_ptr[ptr_off as usize + t] as usize;
                    let hi = self.sp_ptr[ptr_off as usize + t + 1] as usize;
                    for e in lo..hi {
                        f(r, self.sp_col[e] as usize, self.sp_val[e]);
                    }
                }
            }
        }
    }

    /// Dense-block payload (padded into caller buffers by the scheduler).
    pub fn dense_slice(&self, t: usize) -> Option<&[f32]> {
        let b = &self.blocks[t];
        match b.kind {
            BlockKind::Dense { off } => {
                Some(&self.dense[off as usize..off as usize + b.rows.len() * b.cols.len()])
            }
            BlockKind::Sparse { .. } => None,
        }
    }

    /// Flat (single-level, row-major block) schedule — the CSB ablation.
    pub fn flat_order(&self) -> Vec<u32> {
        let mut idx: Vec<u32> = (0..self.blocks.len() as u32).collect();
        idx.sort_by_key(|&t| {
            let b = &self.blocks[t as usize];
            (b.tleaf, b.sleaf)
        });
        idx
    }

    /// Sequential multi-level SpMV: `y = A x` (y overwritten).
    pub fn spmv(&self, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        y.fill(0.0);
        for t in 0..self.blocks.len() {
            self.block_matvec(t, x, y);
        }
    }

    /// Sequential SpMV in an explicit block order (ablation hook).
    pub fn spmv_ordered(&self, order: &[u32], x: &[f32], y: &mut [f32]) {
        y.fill(0.0);
        for &t in order {
            self.block_matvec(t as usize, x, y);
        }
    }

    /// Fraction of nonzeros living in dense-stored blocks (from the
    /// build-time [`CsbStats`]; no recomputation).
    pub fn dense_fraction(&self) -> f64 {
        self.stats.dense_fraction()
    }

    /// Index-space coverage of the stored blocks: `(covered, total)` where
    /// `covered` is the summed `rows x cols` area of every block and
    /// `total = rows·cols` of the whole matrix.  Everything outside the
    /// covered area is implicitly zero — under a kNN-truncated profile
    /// that is the dropped far field (which `hmat` compresses in
    /// full-kernel mode), so the gap between the two numbers is exactly
    /// the near/far split that `describe()` and the `reorder` CLI report
    /// surface.
    pub fn coverage(&self) -> (u64, u64) {
        (self.stats.covered_area, self.stats.total_area)
    }

    /// `covered / total` of [`HierCsb::coverage`] (0 for an empty matrix).
    pub fn covered_fraction(&self) -> f64 {
        self.stats.covered_fraction()
    }

    /// Stats line for logs/benches — formatted from the build-time
    /// [`CsbStats`], the same numbers the `obs` snapshot carries.
    pub fn describe(&self) -> String {
        let (covered, total) = self.coverage();
        format!(
            "blocks={} tgt_leaves={} src_leaves={} dense_frac={:.2} avg_block_nnz={:.1} \
             covered={covered}/{total} ({:.2}%)",
            self.blocks.len(),
            self.tgt_leaves.len(),
            self.src_leaves.len(),
            self.dense_fraction(),
            self.nnz as f64 / self.blocks.len().max(1) as f64,
            self.covered_fraction() * 100.0
        )
    }
}

/// Per-(target leaf, source leaf) occupancy from the count pass — shared by
/// the from-scratch build and the incremental update (`csb::update`), which
/// reconstructs these for reused leaves instead of rescanning their rows.
#[derive(Clone, Default)]
pub(crate) struct LeafCount {
    pub sl: u32,
    pub nnz: u32,
    pub rows: u32,
    /// Last row counted for this block (count-pass scratch).
    pub last_row: u32,
}

/// Count pass for one target leaf: the occupied source leaves of the leaf's
/// rows, with per-block nnz and occupied-row counts, ascending `sl`.  A pure
/// function of the leaf's own rows, so the result is thread-count
/// independent.  The per-leaf state is a sorted vec of just the *occupied*
/// blocks — O(nnz + blocks) per leaf, not O(src_leaves) scratch per leaf,
/// which would make the count pass quadratic in the leaf count at scale.
/// CSR rows have ascending columns, so equal source leaves arrive in runs
/// and the cached index hits for all but the first entry of each run.
pub(crate) fn count_target_leaf(a: &Csr, span: Span, col_leaf: &[u32]) -> Vec<LeafCount> {
    let mut counts: Vec<LeafCount> = Vec::new();
    for i in span.lo..span.hi {
        let (cols, _) = a.row(i as usize);
        let mut cached: Option<usize> = None;
        for &j in cols {
            let sl = col_leaf[j as usize];
            let li = match cached {
                Some(li) if counts[li].sl == sl => li,
                _ => match counts.binary_search_by_key(&sl, |c| c.sl) {
                    Ok(li) => li,
                    Err(pos) => {
                        counts.insert(
                            pos,
                            LeafCount {
                                sl,
                                nnz: 0,
                                rows: 0,
                                last_row: u32::MAX,
                            },
                        );
                        pos
                    }
                },
            };
            counts[li].nnz += 1;
            if counts[li].last_row != i {
                counts[li].last_row = i;
                counts[li].rows += 1;
            }
            cached = Some(li);
        }
    }
    counts
}

/// Output of the exclusive scan: block metadata and arena extents, a pure
/// function of `(order, per-leaf counts, spans, dense_threshold)`.
pub(crate) struct Layout {
    pub blocks: Vec<LeafBlock>,
    /// Per block, base offset into the entry arenas (sparse blocks only).
    pub ent_base: Vec<u32>,
    pub panel_off: Vec<u32>,
    pub panel_total: usize,
    pub dense_len: usize,
    pub rows_len: usize,
    pub ptr_len: usize,
    pub ents_len: usize,
    pub by_target: Vec<Vec<u32>>,
    /// Per target leaf, (source leaf → block index), sorted for the
    /// fill-pass lookups.
    pub lookup: Vec<Vec<(u32, u32)>>,
}

pub(crate) fn scan_layout(
    order: &[(u32, u32)],
    per_leaf: &[Vec<LeafCount>],
    tgt_leaves: &[Span],
    src_leaves: &[Span],
    dense_threshold: f64,
) -> Layout {
    let nt = tgt_leaves.len();
    let mut blocks: Vec<LeafBlock> = Vec::with_capacity(order.len());
    let mut ent_base: Vec<u32> = Vec::with_capacity(order.len());
    let mut panel_off: Vec<u32> = Vec::with_capacity(order.len());
    let mut panel_total = 0usize;
    let (mut dense_len, mut rows_len, mut ptr_len, mut ents_len) = (0usize, 0usize, 0usize, 0usize);
    for &(tl, sl) in order {
        let counts = &per_leaf[tl as usize];
        let c = &counts[counts
            .binary_search_by_key(&sl, |c| c.sl)
            .expect("traversal emitted an uncounted block")];
        let rows = tgt_leaves[tl as usize];
        let cols = src_leaves[sl as usize];
        let area = rows.len() * cols.len();
        let density = c.nnz as f64 / area as f64;
        let kind = if density >= dense_threshold {
            let off = dense_len as u32;
            dense_len += area;
            ent_base.push(0);
            panel_off.push(panel_total as u32);
            panel_total += panel::panel_len(rows.len(), cols.len());
            BlockKind::Dense { off }
        } else {
            let k = BlockKind::Sparse {
                row_off: rows_len as u32,
                row_cnt: c.rows,
                ptr_off: ptr_len as u32,
            };
            rows_len += c.rows as usize;
            ptr_len += c.rows as usize + 1;
            ent_base.push(ents_len as u32);
            ents_len += c.nnz as usize;
            panel_off.push(panel::NO_PANEL);
            k
        };
        blocks.push(LeafBlock {
            tleaf: tl,
            sleaf: sl,
            rows,
            cols,
            nnz: c.nnz,
            kind,
        });
    }
    assert!(panel_total <= u32::MAX as usize, "panel arena exceeds u32 offsets");
    let mut by_target: Vec<Vec<u32>> = vec![Vec::new(); nt];
    for (t, b) in blocks.iter().enumerate() {
        by_target[b.tleaf as usize].push(t as u32);
    }
    let mut lookup: Vec<Vec<(u32, u32)>> = vec![Vec::new(); nt];
    for (t, b) in blocks.iter().enumerate() {
        lookup[b.tleaf as usize].push((b.sleaf, t as u32));
    }
    for l in lookup.iter_mut() {
        l.sort_unstable();
    }
    Layout {
        blocks,
        ent_base,
        panel_off,
        panel_total,
        dense_len,
        rows_len,
        ptr_len,
        ents_len,
        by_target,
        lookup,
    }
}

/// Fill pass for one target leaf: scatter the leaf's rows of `a` into the
/// (full) arena slices.  Writes land only in regions of blocks owned by
/// this leaf; a fixed row scan, so the output is bit-identical regardless
/// of which thread runs it.
#[allow(clippy::too_many_arguments)]
pub(crate) fn fill_target_leaf(
    a: &Csr,
    span: Span,
    lst: &[(u32, u32)],
    col_leaf: &[u32],
    blocks: &[LeafBlock],
    ent_base: &[u32],
    dense_all: &mut [f32],
    rows_all: &mut [u16],
    ptr_all: &mut [u32],
    col_all: &mut [u16],
    val_all: &mut [f32],
) {
    let mut ents_written = vec![0u32; lst.len()];
    let mut rows_written = vec![0u32; lst.len()];
    let mut cur_row = vec![u32::MAX; lst.len()];
    for &(_, bi) in lst {
        if let BlockKind::Sparse { ptr_off, .. } = blocks[bi as usize].kind {
            // ptr[0] = block entry base; ptr[1 + t] (filled below) = end of
            // occupied row t.
            ptr_all[ptr_off as usize] = ent_base[bi as usize];
        }
    }
    for i in span.lo..span.hi {
        let local_row = i - span.lo;
        let (cols, vals) = a.row(i as usize);
        // Same run cache as the count pass: ascending columns deliver equal
        // source leaves in runs, so the lookup is O(1) amortized instead of
        // a search per nonzero.
        let mut cached = usize::MAX;
        for (&j, &v) in cols.iter().zip(vals) {
            let sl = col_leaf[j as usize];
            let li = if cached != usize::MAX && lst[cached].0 == sl {
                cached
            } else {
                lst.binary_search_by_key(&sl, |e| e.0)
                    .expect("entry in uncounted block")
            };
            cached = li;
            let bi = lst[li].1 as usize;
            let b = &blocks[bi];
            match b.kind {
                BlockKind::Dense { off } => {
                    let w = b.cols.len();
                    let c = (j - b.cols.lo) as usize;
                    dense_all[off as usize + local_row as usize * w + c] += v;
                }
                BlockKind::Sparse {
                    row_off, ptr_off, ..
                } => {
                    let base = ent_base[bi];
                    if cur_row[li] != i {
                        cur_row[li] = i;
                        rows_all[row_off as usize + rows_written[li] as usize] = local_row as u16;
                        rows_written[li] += 1;
                    }
                    let e = (base + ents_written[li]) as usize;
                    col_all[e] = (j - b.cols.lo) as u16;
                    val_all[e] = v;
                    ents_written[li] += 1;
                    ptr_all[ptr_off as usize + rows_written[li] as usize] = base + ents_written[li];
                }
            }
        }
    }
}

/// Pack pass: tile-major panel copies of every dense block (parallel over
/// blocks; a pure function of the dense arena, bit-identical across thread
/// counts).
pub(crate) fn pack_panels(
    pool: &ThreadPool,
    blocks: &[LeafBlock],
    panel_off: &[u32],
    dense: &[f32],
    panel_total: usize,
) -> panel::AlignedF32 {
    let mut panel_data = panel::AlignedF32::zeroed(panel_total);
    {
        let pp = SendPtr(panel_data.as_mut_slice().as_mut_ptr());
        let ppr = &pp;
        pool.for_each_chunked(blocks.len(), 8, |t| {
            let b = &blocks[t];
            if let BlockKind::Dense { off } = b.kind {
                let (rn, cn) = (b.rows.len(), b.cols.len());
                let po = panel_off[t] as usize;
                let plen = panel::panel_len(rn, cn);
                // SAFETY: the worker materializes only its own block's
                // panel region; regions are disjoint per block, so no two
                // live slices overlap.
                let out: &mut [f32] = unsafe { std::slice::from_raw_parts_mut(ppr.0.add(po), plen) };
                panel::pack_panel(&dense[off as usize..off as usize + rn * cn], rn, cn, out);
            }
        });
    }
    panel_data
}

/// Profile stats of a block layout (a pure function of the blocks and the
/// target cut) — computed once at build/update, published by the caller.
pub(crate) fn compute_stats(
    nnz: usize,
    rows: usize,
    cols: usize,
    blocks: &[LeafBlock],
    tgt_tree: &BoxTree,
    tgt_leaf_ids: &[u32],
    panel_total: usize,
) -> CsbStats {
    let depth: Vec<u32> = tgt_leaf_ids.iter().map(|&id| node_depth(tgt_tree, id)).collect();
    let max_depth = depth.iter().copied().max().unwrap_or(0) as usize;
    let mut level_rows: Vec<CsbLevelStats> = (0..=max_depth)
        .map(|l| CsbLevelStats {
            level: l as u32,
            ..CsbLevelStats::default()
        })
        .collect();
    let mut stats = CsbStats {
        nnz: nnz as u64,
        total_area: rows as u64 * cols as u64,
        panel_bytes: panel_total as u64 * 4,
        ..CsbStats::default()
    };
    for b in blocks {
        let area = b.rows.len() as u64 * b.cols.len() as u64;
        stats.covered_area += area;
        let row = &mut level_rows[depth[b.tleaf as usize] as usize];
        row.blocks += 1;
        row.nnz += b.nnz as u64;
        row.cells += area;
        if b.is_dense() {
            stats.dense_blocks += 1;
            stats.dense_cells += area;
            stats.dense_nnz += b.nnz as u64;
            row.dense_blocks += 1;
        } else {
            stats.sparse_blocks += 1;
        }
    }
    stats.levels = level_rows.into_iter().filter(|r| r.blocks > 0).collect();
    stats
}

/// Depth of tree node `id` (root = 0) via parent walk — the level label of
/// the per-level profile counters.
fn node_depth(tree: &BoxTree, id: u32) -> u32 {
    let mut d = 0;
    let mut n = id;
    loop {
        let p = tree.nodes[n as usize].parent;
        if p == n {
            break;
        }
        n = p;
        d += 1;
    }
    d
}

/// Map each index to its leaf ordinal via span scan.
pub(crate) fn leaf_lookup(leaves: &[Span], n: usize) -> Vec<u32> {
    let mut out = vec![0u32; n];
    for (ord, sp) in leaves.iter().enumerate() {
        for i in sp.lo..sp.hi {
            out[i as usize] = ord as u32;
        }
    }
    out
}

/// Recursive dual-tree descent emitting (block-row ordinal, block-col
/// ordinal) pairs over the two size cuts; pairs with no nonzeros are pruned
/// via a bottom-up occupancy set.
pub(crate) fn multilevel_order(
    tt: &BoxTree,
    st: &BoxTree,
    tgt_leaf_ids: &[u32],
    src_leaf_ids: &[u32],
    blocks: &[(u32, u32)],
) -> Vec<(u32, u32)> {
    use std::collections::HashSet;
    // leaf ordinal -> node id, and node id -> leaf ordinal
    let mut t_ord: HashMap<u32, u32> = HashMap::new();
    for (o, &id) in tgt_leaf_ids.iter().enumerate() {
        t_ord.insert(id, o as u32);
    }
    let mut s_ord: HashMap<u32, u32> = HashMap::new();
    for (o, &id) in src_leaf_ids.iter().enumerate() {
        s_ord.insert(id, o as u32);
    }

    // Occupied (t node, s node) pairs, propagated to ancestors.
    let mut occupied: HashSet<(u32, u32)> = HashSet::new();
    for &(btl, bsl) in blocks {
        let mut tn = tgt_leaf_ids[btl as usize];
        loop {
            let mut sn = src_leaf_ids[bsl as usize];
            loop {
                if !occupied.insert((tn, sn)) {
                    // ancestors already present? still need to walk up this
                    // source chain because different leaves share ancestors
                }
                let sp = st.nodes[sn as usize].parent;
                if sp == sn {
                    break;
                }
                sn = sp;
            }
            let tp = tt.nodes[tn as usize].parent;
            if tp == tn {
                break;
            }
            tn = tp;
        }
    }

    let mut out = Vec::with_capacity(blocks.len());
    descend(tt, st, 0, 0, &occupied, &t_ord, &s_ord, &mut out);
    out
}

#[allow(clippy::too_many_arguments)]
fn descend(
    tt: &BoxTree,
    st: &BoxTree,
    tn: u32,
    sn: u32,
    occupied: &std::collections::HashSet<(u32, u32)>,
    t_ord: &HashMap<u32, u32>,
    s_ord: &HashMap<u32, u32>,
    out: &mut Vec<(u32, u32)>,
) {
    if !occupied.contains(&(tn, sn)) {
        return;
    }
    // Cut membership terminates descent (cut nodes are the block spans).
    let t_leaf = t_ord.contains_key(&tn);
    let s_leaf = s_ord.contains_key(&sn);
    match (t_leaf, s_leaf) {
        (true, true) => {
            out.push((t_ord[&tn], s_ord[&sn]));
        }
        (false, true) => {
            for &c in &tt.nodes[tn as usize].children {
                descend(tt, st, c, sn, occupied, t_ord, s_ord, out);
            }
        }
        (true, false) => {
            for &c in &st.nodes[sn as usize].children {
                descend(tt, st, tn, c, occupied, t_ord, s_ord, out);
            }
        }
        (false, false) => {
            // Split both: child-pair blocks complete a parent pair before
            // moving on (the multi-level schedule).
            for &tc in &tt.nodes[tn as usize].children {
                for &sc in &st.nodes[sn as usize].children {
                    descend(tt, st, tc, sc, occupied, t_ord, s_ord, out);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;
    use crate::knn::exact::knn_graph;
    use crate::order::Pipeline;

    fn setup(n: usize, leaf: usize) -> (Csr, HierCsb) {
        let ds = SynthSpec::blobs(n, 3, 4, 11).generate();
        let g = knn_graph(&ds, 8, 2);
        let a = Csr::from_knn(&g, n).symmetrized();
        let r = Pipeline::dual_tree(3).run(&ds, &a);
        let tree = r.tree.as_ref().unwrap();
        let csb = HierCsb::build(&r.reordered, tree, tree, leaf);
        (r.reordered, csb)
    }

    #[test]
    fn block_nnz_sums_to_total() {
        let (a, csb) = setup(400, 32);
        let total: u64 = csb.blocks.iter().map(|b| b.nnz as u64).sum();
        assert_eq!(total as usize, a.nnz());
    }

    #[test]
    fn spmv_matches_csr_reference() {
        let (a, csb) = setup(500, 32);
        let mut rng = crate::util::rng::Rng::new(5);
        let x: Vec<f32> = (0..a.cols).map(|_| rng.f32()).collect();
        let want = a.matvec_ref(&x);
        let mut got = vec![0.0f32; a.rows];
        csb.spmv(&x, &mut got);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-3 * (1.0 + w.abs()), "{g} vs {w}");
        }
    }

    #[test]
    fn flat_order_same_result() {
        let (a, csb) = setup(300, 16);
        let mut rng = crate::util::rng::Rng::new(6);
        let x: Vec<f32> = (0..a.cols).map(|_| rng.f32()).collect();
        let mut y1 = vec![0.0f32; a.rows];
        let mut y2 = vec![0.0f32; a.rows];
        csb.spmv(&x, &mut y1);
        let flat = csb.flat_order();
        csb.spmv_ordered(&flat, &x, &mut y2);
        for (a, b) in y1.iter().zip(&y2) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn by_target_covers_all_blocks() {
        let (_, csb) = setup(350, 32);
        let total: usize = csb.by_target.iter().map(|v| v.len()).sum();
        assert_eq!(total, csb.blocks.len());
        for (tl, list) in csb.by_target.iter().enumerate() {
            for &t in list {
                assert_eq!(csb.blocks[t as usize].tleaf as usize, tl);
            }
        }
    }

    #[test]
    fn dense_blocks_appear_on_clustered_data() {
        // strongly clustered data + symmetrized kNN → diagonal blocks dense
        // under the PJRT-path threshold (0.25); with k=8 and ~32-point
        // blocks the diagonal density is ~0.5.
        let ds = SynthSpec::blobs(400, 3, 4, 11).generate();
        let g = knn_graph(&ds, 8, 2);
        let a = Csr::from_knn(&g, 400).symmetrized();
        let r = Pipeline::dual_tree(3).run(&ds, &a);
        let tree = r.tree.as_ref().unwrap();
        let csb = HierCsb::build_with(&r.reordered, tree, tree, 32, 0.25);
        assert!(
            csb.dense_fraction() > 0.3,
            "expected dense blocks, got {}",
            csb.describe()
        );
    }

    #[test]
    fn multilevel_order_groups_target_parents() {
        // Blocks of the same target leaf must appear consecutively *or* at
        // least the traversal must not round-robin leaves: count target
        // switches; multilevel should have far fewer than random order.
        let (_, csb) = setup(600, 16);
        let switches = csb
            .blocks
            .windows(2)
            .filter(|w| w[0].tleaf != w[1].tleaf)
            .count();
        // flat row-major order = minimal switches (= #leaves-1 at least);
        // multilevel is allowed more, but must be within 4x of block-count/leaf bound.
        assert!(
            switches < csb.blocks.len(),
            "degenerate traversal: {switches} switches over {} blocks",
            csb.blocks.len()
        );
    }

    #[test]
    fn spmm_columns_bitexact_with_spmv() {
        // The acceptance bar of the multi-RHS path: every column of
        // spmm(k) reproduces the scalar spmv bit-for-bit (same chains).
        let (a, csb) = setup(500, 32);
        let mut rng = crate::util::rng::Rng::new(21);
        for k in [1usize, 2, 3, 7, 8, 11] {
            let x: Vec<f32> = (0..a.cols * k).map(|_| rng.f32() - 0.5).collect();
            let mut y = vec![0.0f32; a.rows * k];
            csb.spmm(&x, &mut y, k);
            for j in 0..k {
                let xj: Vec<f32> = (0..a.cols).map(|i| x[i * k + j]).collect();
                let mut yj = vec![0.0f32; a.rows];
                csb.spmv(&xj, &mut yj);
                for i in 0..a.rows {
                    assert_eq!(
                        y[i * k + j].to_bits(),
                        yj[i].to_bits(),
                        "k={k} col={j} row={i}"
                    );
                }
            }
        }
    }

    #[test]
    fn spmm_ordered_flat_matches_multilevel() {
        let (a, csb) = setup(400, 16);
        let mut rng = crate::util::rng::Rng::new(22);
        let k = 4;
        let x: Vec<f32> = (0..a.cols * k).map(|_| rng.f32()).collect();
        let mut y1 = vec![0.0f32; a.rows * k];
        let mut y2 = vec![0.0f32; a.rows * k];
        csb.spmm(&x, &mut y1, k);
        let flat = csb.flat_order();
        csb.spmm_ordered(&flat, &x, &mut y2, k);
        for (a, b) in y1.iter().zip(&y2) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn panels_mirror_dense_blocks() {
        use crate::csb::panel::{panel_len, PANEL_MR};
        let (_, csb) = setup(500, 32);
        for (t, b) in csb.blocks.iter().enumerate() {
            let (rn, cn) = (b.rows.len(), b.cols.len());
            match b.kind {
                BlockKind::Dense { off } => {
                    let p = csb.panels.panel(t, rn, cn).expect("dense block has a panel");
                    assert_eq!(p.len(), panel_len(rn, cn));
                    for r in 0..rn {
                        for c in 0..cn {
                            let want = csb.dense[off as usize + r * cn + c];
                            let got =
                                p[(r / PANEL_MR) * cn * PANEL_MR + c * PANEL_MR + (r % PANEL_MR)];
                            assert_eq!(got.to_bits(), want.to_bits(), "block {t} at ({r},{c})");
                        }
                    }
                }
                BlockKind::Sparse { .. } => {
                    assert!(csb.panels.panel(t, rn, cn).is_none());
                }
            }
        }
    }

    #[test]
    fn dispatched_spmm_matches_scalar_within_tolerance() {
        // The dispatch seam itself: whatever kernel::detect() offers on
        // this CPU, block_matmul_with must agree with the scalar reference
        // (exact parity bounds live in rust/tests/kernel_parity.rs).
        let (a, csb) = setup(400, 32);
        let (dispatch, _) = kernel::KernelKind::Auto.resolve();
        let mut rng = crate::util::rng::Rng::new(29);
        for k in [1usize, 3, 8] {
            let x: Vec<f32> = (0..a.cols * k).map(|_| rng.f32() - 0.5).collect();
            let mut y_ref = vec![0.0f32; a.rows * k];
            csb.spmm(&x, &mut y_ref, k);
            let mut y = vec![0.0f32; a.rows * k];
            for t in 0..csb.blocks.len() {
                csb.block_matmul_with(t, &x, &mut y, k, dispatch);
            }
            for (g, w) in y.iter().zip(&y_ref) {
                assert!((g - w).abs() < 1e-5 * (1.0 + w.abs()), "k={k}: {g} vs {w}");
            }
        }
    }

    #[test]
    fn build_par_bitidentical_with_sequential() {
        let ds = SynthSpec::blobs(500, 3, 4, 11).generate();
        let g = knn_graph(&ds, 8, 2);
        let a = Csr::from_knn(&g, 500).symmetrized();
        let r = Pipeline::dual_tree(3).run(&ds, &a);
        let tree = r.tree.as_ref().unwrap();
        let seq = HierCsb::build_with(&r.reordered, tree, tree, 32, 0.4);
        for threads in [1usize, 2, 8] {
            let par = HierCsb::build_with_par(&r.reordered, tree, tree, 32, 0.4, threads);
            assert_eq!(seq.tgt_leaves, par.tgt_leaves, "threads={threads}");
            assert_eq!(seq.src_leaves, par.src_leaves);
            assert_eq!(seq.blocks, par.blocks, "block layout, threads={threads}");
            assert_eq!(seq.by_target, par.by_target);
            assert_eq!(seq.sp_rows, par.sp_rows);
            assert_eq!(seq.sp_ptr, par.sp_ptr);
            assert_eq!(seq.sp_col, par.sp_col);
            assert_eq!(seq.dense.len(), par.dense.len());
            assert!(
                seq.dense
                    .iter()
                    .zip(&par.dense)
                    .all(|(x, y)| x.to_bits() == y.to_bits()),
                "dense arena differs, threads={threads}"
            );
            assert_eq!(seq.sp_val.len(), par.sp_val.len());
            assert!(seq
                .sp_val
                .iter()
                .zip(&par.sp_val)
                .all(|(x, y)| x.to_bits() == y.to_bits()));
            assert_eq!(seq.panels.off, par.panels.off, "panel offsets, threads={threads}");
            let sp = seq.panels.data.as_slice();
            let pp = par.panels.data.as_slice();
            assert_eq!(sp.len(), pp.len());
            assert!(
                sp.iter().zip(pp).all(|(x, y)| x.to_bits() == y.to_bits()),
                "panel arena differs, threads={threads}"
            );
        }
    }

    #[test]
    fn coverage_counts_block_areas_against_total() {
        let (a, csb) = setup(400, 32);
        let (covered, total) = csb.coverage();
        assert_eq!(total, (a.rows * a.cols) as u64);
        let manual: u64 = csb
            .blocks
            .iter()
            .map(|b| b.rows.len() as u64 * b.cols.len() as u64)
            .sum();
        assert_eq!(covered, manual);
        // blocks only exist where nonzeros are, so coverage is bounded by
        // the full matrix and reaches at least the nnz footprint
        assert!(covered <= total);
        assert!(covered >= a.nnz() as u64, "covered area below nnz count");
        let frac = csb.covered_fraction();
        assert!(frac > 0.0 && frac <= 1.0);
        // describe() surfaces the same numbers
        let d = csb.describe();
        assert!(
            d.contains(&format!("covered={covered}/{total}")),
            "describe() missing coverage: {d}"
        );
    }

    #[test]
    fn dense_threshold_extremes() {
        let ds = SynthSpec::blobs(200, 2, 3, 3).generate();
        let g = knn_graph(&ds, 5, 1);
        let a = Csr::from_knn(&g, 200).symmetrized();
        let r = Pipeline::dual_tree(2).run(&ds, &a);
        let tree = r.tree.as_ref().unwrap();
        let all_dense = HierCsb::build_with(&r.reordered, tree, tree, 32, 0.0);
        let all_sparse = HierCsb::build_with(&r.reordered, tree, tree, 32, 1.1);
        assert!((all_dense.dense_fraction() - 1.0).abs() < 1e-9);
        assert_eq!(all_sparse.dense_fraction(), 0.0);
        // both compute the same product
        let mut rng = crate::util::rng::Rng::new(7);
        let x: Vec<f32> = (0..200).map(|_| rng.f32()).collect();
        let mut y1 = vec![0.0f32; 200];
        let mut y2 = vec![0.0f32; 200];
        all_dense.spmv(&x, &mut y1);
        all_sparse.spmv(&x, &mut y2);
        for (a, b) in y1.iter().zip(&y2) {
            assert!((a - b).abs() < 1e-4);
        }
    }
}
