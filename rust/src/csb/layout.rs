//! Hierarchical vector layout: charge and potential vectors placed in
//! memory according to the cluster structure (§2.4 "we reorder the charge
//! and potential vectors hierarchically in memory").
//!
//! With the tree permutation `perm` (tree position k holds original index
//! perm[k]):
//! * `to_tree_order`   — gather `x_tree[k] = x[perm[k]]`
//! * `from_tree_order` — scatter `y[perm[k]] = y_tree[k]`

/// Gather a vector into tree order.
pub fn to_tree_order<T: Copy>(x: &[T], perm: &[usize]) -> Vec<T> {
    assert_eq!(x.len(), perm.len());
    perm.iter().map(|&p| x[p]).collect()
}

/// Scatter a tree-ordered vector back to original order.
pub fn from_tree_order<T: Copy + Default>(x_tree: &[T], perm: &[usize]) -> Vec<T> {
    assert_eq!(x_tree.len(), perm.len());
    let mut out = vec![T::default(); x_tree.len()];
    for (k, &p) in perm.iter().enumerate() {
        out[p] = x_tree[k];
    }
    out
}

/// Gather rows of a row-major `n x d` coordinate array into tree order.
pub fn rows_to_tree_order(x: &[f32], d: usize, perm: &[usize]) -> Vec<f32> {
    let mut out = Vec::new();
    rows_to_tree_order_into(x, d, perm, &mut out);
    out
}

/// [`rows_to_tree_order`] into a reusable buffer (allocation-free once
/// warm — the per-iteration gather of the mean-shift loop).
pub fn rows_to_tree_order_into(x: &[f32], d: usize, perm: &[usize], out: &mut Vec<f32>) {
    assert_eq!(x.len(), perm.len() * d);
    out.clear();
    out.reserve(x.len());
    for &p in perm {
        out.extend_from_slice(&x[p * d..(p + 1) * d]);
    }
}

/// Scatter rows of a tree-ordered `n x d` array back to original order.
pub fn rows_from_tree_order(xt: &[f32], d: usize, perm: &[usize]) -> Vec<f32> {
    let mut out = vec![0.0f32; xt.len()];
    rows_from_tree_order_into(xt, d, perm, &mut out);
    out
}

/// [`rows_from_tree_order`] into a caller-owned, pre-sized buffer
/// (`perm.len() * d`) — every row is overwritten, so the scatter can go
/// straight into a live coordinate array (the mean-shift loop writes the
/// shifted means back into its `Dataset` buffer this way).
pub fn rows_from_tree_order_into(xt: &[f32], d: usize, perm: &[usize], out: &mut [f32]) {
    assert_eq!(xt.len(), perm.len() * d);
    assert_eq!(out.len(), xt.len());
    for (k, &p) in perm.iter().enumerate() {
        out[p * d..(p + 1) * d].copy_from_slice(&xt[k * d..(k + 1) * d]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn gather_scatter_roundtrip() {
        let mut rng = Rng::new(1);
        let perm = rng.permutation(97);
        let x: Vec<f32> = (0..97).map(|_| rng.f32()).collect();
        let xt = to_tree_order(&x, &perm);
        assert_eq!(from_tree_order(&xt, &perm), x);
    }

    #[test]
    fn rows_roundtrip() {
        let mut rng = Rng::new(2);
        let perm = rng.permutation(41);
        let x: Vec<f32> = (0..41 * 3).map(|_| rng.f32()).collect();
        let xt = rows_to_tree_order(&x, 3, &perm);
        assert_eq!(rows_from_tree_order(&xt, 3, &perm), x);
    }

    #[test]
    fn gather_semantics() {
        // perm = [2,0,1]: tree position 0 holds original index 2.
        let x = [10.0f32, 20.0, 30.0];
        let xt = to_tree_order(&x, &[2, 0, 1]);
        assert_eq!(xt, vec![30.0, 10.0, 20.0]);
    }
}
