//! Apply-side micro-kernels and their runtime dispatch.
//!
//! Every block product in the system bottoms out in one of two granules:
//!
//! * **dense** — `Y += D · X` for a dense-stored block against `k` RHS
//!   columns ([`dense_gemm_acc`] on row-major values, or the AVX2 panel
//!   kernel [`avx2::panel_gemm_acc`] on the tile-major panels packed at
//!   build time by [`crate::csb::panel`]);
//! * **DCSR** — row-wise `k`-wide AXPYs over a block's local CSR with
//!   `u16` column indices ([`dcsr_gemm_acc`] / [`avx2::dcsr_gemm_acc`]).
//!
//! The scalar variants are the **always-available golden reference**: they
//! keep a single sequential accumulation chain per output in column order,
//! so `k = 1` reproduces the scalar matvec bit-for-bit and results are
//! bit-identical across thread counts.  The AVX2+FMA variants keep the
//! same per-output chain *order* but contract multiply-add pairs (FMA), so
//! they match the scalar reference to relative tolerance, not bitwise —
//! which is why [`KernelKind::Scalar`] exists as a CLI-pinnable choice for
//! determinism-sensitive runs while SIMD-vs-scalar parity is
//! tolerance-checked (`rust/tests/kernel_parity.rs`, repo-root
//! EXPERIMENTS.md §Kernel dispatch).

/// RHS register-block width of the micro-GEMM: 8 f32 accumulators fit one
/// AVX2 register (or two NEON quads) with room for the 4 broadcast values
/// of the unrolled reduction, so the inner loops stay in registers.
pub const GEMM_KC: usize = 8;

/// Kernel selection as requested (CLI `--kernel {auto,simd,scalar}`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum KernelKind {
    /// Best available: SIMD when the CPU supports it, scalar otherwise.
    #[default]
    Auto,
    /// SIMD requested explicitly (still falls back to scalar when the CPU
    /// lacks AVX2+FMA, but the fallback reason is surfaced).
    Simd,
    /// Pin the scalar reference kernel (bit-exact across thread counts and
    /// identical to the pre-SIMD behavior).
    Scalar,
}

impl KernelKind {
    /// Parse a CLI value.
    pub fn parse(s: &str) -> Result<KernelKind, String> {
        match s.to_ascii_lowercase().as_str() {
            "auto" => Ok(KernelKind::Auto),
            "simd" => Ok(KernelKind::Simd),
            "scalar" => Ok(KernelKind::Scalar),
            other => Err(format!("unknown kernel '{other}' (auto|simd|scalar)")),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            KernelKind::Auto => "auto",
            KernelKind::Simd => "simd",
            KernelKind::Scalar => "scalar",
        }
    }

    /// Resolve to a concrete dispatch.  The second field is the reason a
    /// non-scalar request fell back to the scalar kernel (`None` when the
    /// SIMD path is live or scalar was requested).
    pub fn resolve(&self) -> (Dispatch, Option<&'static str>) {
        match self {
            KernelKind::Scalar => (Dispatch::Scalar, None),
            KernelKind::Auto | KernelKind::Simd => match detect() {
                Dispatch::Avx2 => (Dispatch::Avx2, None),
                Dispatch::Scalar => (Dispatch::Scalar, Some(FALLBACK_REASON)),
            },
        }
    }
}

/// A concrete kernel implementation chosen at runtime.
///
/// Construct `Avx2` via [`detect`]/[`KernelKind::resolve`].  A hand-built
/// `Avx2` on an unsupported CPU is still *sound*: every dispatch site
/// re-verifies with [`detect`] (cached probe) and falls back to the
/// scalar kernel, so the `#[target_feature]` code is never reached
/// without CPU support.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dispatch {
    Scalar,
    Avx2,
}

impl Dispatch {
    pub fn label(&self) -> &'static str {
        match self {
            Dispatch::Scalar => "scalar",
            Dispatch::Avx2 => "avx2",
        }
    }
}

#[cfg(target_arch = "x86_64")]
const FALLBACK_REASON: &str = "cpu lacks avx2+fma";
#[cfg(not(target_arch = "x86_64"))]
const FALLBACK_REASON: &str = "non-x86_64 target (no simd kernel built)";

/// Probe the running CPU for the SIMD kernel's feature set.
pub fn detect() -> Dispatch {
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2")
            && std::arch::is_x86_feature_detected!("fma")
        {
            return Dispatch::Avx2;
        }
    }
    Dispatch::Scalar
}

/// Register-blocked dense micro-GEMM granule: `Y += D · X` for a row-major
/// `nrows x ncols` block `d` against `k` RHS columns (`x`: `ncols x k`,
/// `y`: `nrows x k`, row-major).
///
/// RHS columns are processed in register blocks of [`GEMM_KC`]; the
/// reduction over `ncols` is 4×-unrolled.  Each (row, rhs) output keeps a
/// **single sequential accumulation chain** in column order — the same
/// op sequence as the scalar dense matvec — so `k = 1` reproduces
/// `HierCsb::block_matvec` bit-for-bit while still reusing every loaded
/// matrix value across all `k` columns (the GEMM arithmetic-intensity win).
pub fn dense_gemm_acc(d: &[f32], nrows: usize, ncols: usize, x: &[f32], k: usize, y: &mut [f32]) {
    debug_assert!(d.len() >= nrows * ncols);
    debug_assert!(x.len() >= ncols * k);
    debug_assert!(y.len() >= nrows * k);
    let mut j0 = 0;
    while j0 < k {
        let kc = GEMM_KC.min(k - j0);
        for r in 0..nrows {
            let row = &d[r * ncols..(r + 1) * ncols];
            let mut acc = [0.0f32; GEMM_KC];
            let acc = &mut acc[..kc];
            let mut c = 0;
            while c + 4 <= ncols {
                let d0 = row[c];
                let d1 = row[c + 1];
                let d2 = row[c + 2];
                let d3 = row[c + 3];
                let x0 = &x[c * k + j0..][..kc];
                let x1 = &x[(c + 1) * k + j0..][..kc];
                let x2 = &x[(c + 2) * k + j0..][..kc];
                let x3 = &x[(c + 3) * k + j0..][..kc];
                for (a, &xv) in acc.iter_mut().zip(x0) {
                    *a += d0 * xv;
                }
                for (a, &xv) in acc.iter_mut().zip(x1) {
                    *a += d1 * xv;
                }
                for (a, &xv) in acc.iter_mut().zip(x2) {
                    *a += d2 * xv;
                }
                for (a, &xv) in acc.iter_mut().zip(x3) {
                    *a += d3 * xv;
                }
                c += 4;
            }
            while c < ncols {
                let dv = row[c];
                let xr = &x[c * k + j0..][..kc];
                for (a, &xv) in acc.iter_mut().zip(xr) {
                    *a += dv * xv;
                }
                c += 1;
            }
            let out = &mut y[r * k + j0..][..kc];
            for (o, &a) in out.iter_mut().zip(acc.iter()) {
                *o += a;
            }
        }
        j0 += kc;
    }
}

/// DCSR micro-kernel granule: `Y += B · X` for a block-local doubly
/// compressed CSR (`rows`: occupied local rows, `ptr`: absolute entry
/// pointers into the shared `col`/`val` arenas) against `k` RHS columns
/// (`x`: `block_cols x k`, `y`: `block_rows x k`, row-major).
///
/// The one entry point for the sparse-block register loop, shared by
/// `HierCsb::block_matmul` and the engine paths (it used to be duplicated
/// inline).  Per (row, rhs) output: single sequential accumulation chain
/// in entry order — bit-exact with the scalar matvec at `k = 1`.
pub fn dcsr_gemm_acc(
    rows: &[u16],
    ptr: &[u32],
    col: &[u16],
    val: &[f32],
    x: &[f32],
    k: usize,
    y: &mut [f32],
) {
    debug_assert_eq!(ptr.len(), rows.len() + 1);
    let mut j0 = 0;
    while j0 < k {
        let kc = GEMM_KC.min(k - j0);
        for (t, &r) in rows.iter().enumerate() {
            let lo = ptr[t] as usize;
            let hi = ptr[t + 1] as usize;
            let mut acc = [0.0f32; GEMM_KC];
            for e in lo..hi {
                let v = val[e];
                let xr = &x[col[e] as usize * k + j0..][..kc];
                for (a, &xv) in acc[..kc].iter_mut().zip(xr) {
                    *a += v * xv;
                }
            }
            let out = &mut y[r as usize * k + j0..][..kc];
            for (o, &a) in out.iter_mut().zip(&acc[..kc]) {
                *o += a;
            }
        }
        j0 += kc;
    }
}

/// AVX2+FMA variants of the two granules.
///
/// Layout contract: the dense kernel consumes **tile-major panels**
/// ([`crate::csb::panel::pack_panel`]) so each reduction step loads
/// `PANEL_MR` consecutive block values; both kernels handle any RHS width
/// `1 ≤ k` via masked loads/stores on the partial register block (no RHS
/// padding required, so the engine's `n x d` coordinate arrays feed in
/// directly).  All loads are unaligned-tolerant (`loadu`/`maskload`); the
/// build-time panel arena is 32-byte aligned so streaming reads stay
/// cache-line resident.
#[cfg(target_arch = "x86_64")]
pub mod avx2 {
    use super::GEMM_KC;
    use crate::csb::panel::PANEL_MR;
    use std::arch::x86_64::*;

    /// Lane mask enabling the first `kc` of 8 f32 lanes.
    ///
    /// # Safety
    /// Requires AVX2 (callers are `#[target_feature(enable = "avx2")]`).
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn lane_mask(kc: usize) -> __m256i {
        let idx = _mm256_setr_epi32(0, 1, 2, 3, 4, 5, 6, 7);
        _mm256_cmpgt_epi32(_mm256_set1_epi32(kc as i32), idx)
    }

    /// `Y += D · X` over a tile-major panel (see module docs).
    ///
    /// `panel` is `pack_panel`'s output for an `nrows x ncols` block; `x`
    /// is `ncols x k` and `y` is `nrows x k`, both row-major.  Per output
    /// the reduction runs in column order in one accumulator lane, so the
    /// only deviation from [`super::dense_gemm_acc`] is FMA contraction.
    ///
    /// # Safety
    /// The CPU must support AVX2 and FMA ([`super::detect`] returned
    /// [`super::Dispatch::Avx2`]).
    #[target_feature(enable = "avx2")]
    #[target_feature(enable = "fma")]
    pub unsafe fn panel_gemm_acc(
        panel: &[f32],
        nrows: usize,
        ncols: usize,
        x: &[f32],
        k: usize,
        y: &mut [f32],
    ) {
        let ntiles = nrows.div_ceil(PANEL_MR);
        debug_assert!(panel.len() >= ntiles * ncols * PANEL_MR);
        debug_assert!(x.len() >= ncols * k);
        debug_assert!(y.len() >= nrows * k);
        let mut j0 = 0;
        while j0 < k {
            let kc = GEMM_KC.min(k - j0);
            let full = kc == GEMM_KC;
            let m = lane_mask(kc);
            for tile in 0..ntiles {
                let base = tile * ncols * PANEL_MR;
                let mut acc = [_mm256_setzero_ps(); PANEL_MR];
                for c in 0..ncols {
                    let xp = x.as_ptr().add(c * k + j0);
                    let xv = if full {
                        _mm256_loadu_ps(xp)
                    } else {
                        _mm256_maskload_ps(xp, m)
                    };
                    let dp = base + c * PANEL_MR;
                    for (rr, a) in acc.iter_mut().enumerate() {
                        let dv = _mm256_set1_ps(*panel.get_unchecked(dp + rr));
                        *a = _mm256_fmadd_ps(dv, xv, *a);
                    }
                }
                let r0 = tile * PANEL_MR;
                let live = (nrows - r0).min(PANEL_MR);
                for (rr, a) in acc.iter().enumerate().take(live) {
                    let yp = y.as_mut_ptr().add((r0 + rr) * k + j0);
                    if full {
                        _mm256_storeu_ps(yp, _mm256_add_ps(_mm256_loadu_ps(yp), *a));
                    } else {
                        _mm256_maskstore_ps(yp, m, _mm256_add_ps(_mm256_maskload_ps(yp, m), *a));
                    }
                }
            }
            j0 += kc;
        }
    }

    /// AVX2 DCSR kernel: same contract as [`super::dcsr_gemm_acc`], one
    /// broadcast-FMA per stored entry across the `k`-wide register block.
    ///
    /// # Safety
    /// The CPU must support AVX2 and FMA ([`super::detect`] returned
    /// [`super::Dispatch::Avx2`]).
    #[target_feature(enable = "avx2")]
    #[target_feature(enable = "fma")]
    pub unsafe fn dcsr_gemm_acc(
        rows: &[u16],
        ptr: &[u32],
        col: &[u16],
        val: &[f32],
        x: &[f32],
        k: usize,
        y: &mut [f32],
    ) {
        debug_assert_eq!(ptr.len(), rows.len() + 1);
        let mut j0 = 0;
        while j0 < k {
            let kc = GEMM_KC.min(k - j0);
            let full = kc == GEMM_KC;
            let m = lane_mask(kc);
            for (t, &r) in rows.iter().enumerate() {
                let lo = *ptr.get_unchecked(t) as usize;
                let hi = *ptr.get_unchecked(t + 1) as usize;
                let mut acc = _mm256_setzero_ps();
                for e in lo..hi {
                    let xp = x.as_ptr().add(*col.get_unchecked(e) as usize * k + j0);
                    let xv = if full {
                        _mm256_loadu_ps(xp)
                    } else {
                        _mm256_maskload_ps(xp, m)
                    };
                    let dv = _mm256_set1_ps(*val.get_unchecked(e));
                    acc = _mm256_fmadd_ps(dv, xv, acc);
                }
                let yp = y.as_mut_ptr().add(r as usize * k + j0);
                if full {
                    _mm256_storeu_ps(yp, _mm256_add_ps(_mm256_loadu_ps(yp), acc));
                } else {
                    _mm256_maskstore_ps(yp, m, _mm256_add_ps(_mm256_maskload_ps(yp, m), acc));
                }
            }
            j0 += kc;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn naive(d: &[f32], r: usize, c: usize, x: &[f32], k: usize) -> Vec<f64> {
        let mut want = vec![0.0f64; r * k];
        for i in 0..r {
            for j in 0..k {
                for t in 0..c {
                    want[i * k + j] += d[i * c + t] as f64 * x[t * k + j] as f64;
                }
            }
        }
        want
    }

    #[test]
    fn kernel_kind_parses_and_labels() {
        assert_eq!(KernelKind::parse("auto").unwrap(), KernelKind::Auto);
        assert_eq!(KernelKind::parse("SIMD").unwrap(), KernelKind::Simd);
        assert_eq!(KernelKind::parse("scalar").unwrap(), KernelKind::Scalar);
        assert!(KernelKind::parse("mkl").is_err());
        assert_eq!(KernelKind::Scalar.resolve(), (Dispatch::Scalar, None));
        // Auto/Simd resolve to whatever the CPU offers; a scalar resolution
        // must carry the fallback reason for the bench record.
        let (d, why) = KernelKind::Simd.resolve();
        assert_eq!(why.is_some(), d == Dispatch::Scalar);
    }

    #[test]
    fn dense_gemm_matches_naive() {
        // Odd shapes around the 4x unroll and the GEMM_KC register block.
        let mut rng = Rng::new(23);
        let shapes = [(1usize, 1usize, 1usize), (3, 5, 2), (7, 9, 8), (4, 13, 9), (16, 31, 17)];
        for &(r, c, k) in &shapes {
            let d: Vec<f32> = (0..r * c).map(|_| rng.f32() - 0.5).collect();
            let x: Vec<f32> = (0..c * k).map(|_| rng.f32() - 0.5).collect();
            let mut y = vec![0.0f32; r * k];
            dense_gemm_acc(&d, r, c, &x, k, &mut y);
            let want = naive(&d, r, c, &x, k);
            for (g, w) in y.iter().zip(&want) {
                assert!((*g as f64 - w).abs() < 1e-4 * (1.0 + w.abs()), "{g} vs {w}");
            }
        }
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn avx2_panel_gemm_matches_scalar() {
        if detect() != Dispatch::Avx2 {
            eprintln!("skipping: no AVX2+FMA on this CPU");
            return;
        }
        use crate::csb::panel::{pack_panel, panel_len};
        let mut rng = Rng::new(31);
        // rows around PANEL_MR (4), cols around the unroll, k around GEMM_KC
        let shapes = [
            (1usize, 1usize, 1usize),
            (3, 5, 3),
            (4, 8, 8),
            (5, 9, 17),
            (16, 31, 7),
            (13, 4, 8),
        ];
        for &(r, c, k) in &shapes {
            let d: Vec<f32> = (0..r * c).map(|_| rng.f32() - 0.5).collect();
            let x: Vec<f32> = (0..c * k).map(|_| rng.f32() - 0.5).collect();
            let mut panel = vec![0.0f32; panel_len(r, c)];
            pack_panel(&d, r, c, &mut panel);
            let mut y_simd = vec![0.0f32; r * k];
            // SAFETY: detect() confirmed AVX2+FMA above.
            unsafe { avx2::panel_gemm_acc(&panel, r, c, &x, k, &mut y_simd) };
            let mut y_ref = vec![0.0f32; r * k];
            dense_gemm_acc(&d, r, c, &x, k, &mut y_ref);
            for (g, w) in y_simd.iter().zip(&y_ref) {
                assert!((g - w).abs() < 1e-5 * (1.0 + w.abs()), "({r}x{c} k={k}): {g} vs {w}");
            }
        }
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn avx2_dcsr_matches_scalar() {
        if detect() != Dispatch::Avx2 {
            eprintln!("skipping: no AVX2+FMA on this CPU");
            return;
        }
        let mut rng = Rng::new(32);
        for &(nrows, ncols, k) in &[(9usize, 7usize, 1usize), (5, 12, 3), (17, 33, 8), (4, 6, 17)] {
            // random occupied rows with random short entry lists
            let rows: Vec<u16> = (0..nrows).map(|r| r as u16).collect();
            let mut ptr = vec![0u32];
            let mut col = Vec::new();
            let mut val = Vec::new();
            for _ in 0..nrows {
                let cnt = 1 + rng.below(4);
                for _ in 0..cnt {
                    col.push(rng.below(ncols) as u16);
                    val.push(rng.f32() - 0.5);
                }
                ptr.push(col.len() as u32);
            }
            let x: Vec<f32> = (0..ncols * k).map(|_| rng.f32() - 0.5).collect();
            let mut y_simd = vec![0.0f32; nrows * k];
            // SAFETY: detect() confirmed AVX2+FMA above.
            unsafe { avx2::dcsr_gemm_acc(&rows, &ptr, &col, &val, &x, k, &mut y_simd) };
            let mut y_ref = vec![0.0f32; nrows * k];
            dcsr_gemm_acc(&rows, &ptr, &col, &val, &x, k, &mut y_ref);
            for (g, w) in y_simd.iter().zip(&y_ref) {
                assert!(
                    (g - w).abs() < 1e-5 * (1.0 + w.abs()),
                    "({nrows}x{ncols} k={k}): {g} vs {w}"
                );
            }
        }
    }
}
