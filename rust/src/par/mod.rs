//! Minimal data-parallel runtime (rayon substitute, DESIGN.md §5).
//!
//! The paper's parallel experiments need exactly one primitive: a
//! parallel-for over an index range with *static ownership* of output
//! segments (each target cluster is written by exactly one worker), plus a
//! dynamically load-balanced variant for irregular block lists.

pub mod pool;

pub use pool::{parallel_chunks, parallel_for, ThreadPool};
