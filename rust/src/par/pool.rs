//! Scoped parallel-for built on `std::thread::scope` with an atomic
//! chunk-stealing index — dynamic load balancing without a work-stealing
//! deque, which is all the paper's block-irregular workloads need.

use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// First-panic capture for contained workers: parallel drivers record the
/// first panic payload here and re-raise it **once, after the scope joins**
/// — so one panicking task never tears down its sibling workers mid-write
/// (containment), while the caller still observes the panic exactly as
/// before (a `catch_unwind` above the pool — e.g. a serve shard task —
/// sees one panic, and every other task's work completed).
struct PanicSlot(Mutex<Option<Box<dyn std::any::Any + Send>>>);

impl PanicSlot {
    fn new() -> PanicSlot {
        PanicSlot(Mutex::new(None))
    }

    /// Record `p` if it is the first panic (later ones are dropped — the
    /// caller can only re-raise one payload).
    fn record(&self, p: Box<dyn std::any::Any + Send>) {
        // Recover a poisoned slot: it only guards an Option we overwrite.
        let mut slot = self.0.lock().unwrap_or_else(|e| e.into_inner());
        if slot.is_none() {
            *slot = Some(p);
        }
    }

    /// Re-raise the recorded panic, if any (call after the scope joined).
    fn resume(self) {
        if let Some(p) = self.0.into_inner().unwrap_or_else(|e| e.into_inner()) {
            std::panic::resume_unwind(p);
        }
    }
}

/// Raw mutable pointer that may cross scoped-thread boundaries — the
/// crate's one shared wrapper for the disjoint-write parallel pattern: a
/// caller partitions an output buffer into non-overlapping regions (target-
/// leaf row spans, pre-reserved subtree ranges, arena block regions, …),
/// hands the base pointer to scoped workers, and each worker reconstructs
/// a slice but writes only the region it owns.
///
/// SAFETY contract for every use site: regions written through the pointer
/// must be disjoint across concurrently running tasks, and the underlying
/// allocation must outlive the thread scope (guaranteed by
/// `std::thread::scope` joining before the buffer is dropped).
pub struct SendPtr<T>(pub *mut T);
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

/// Number of worker threads to use by default: the machine's logical cores,
/// clamped by the `NNI_THREADS` environment variable when set.
pub fn default_threads() -> usize {
    if let Ok(s) = std::env::var("NNI_THREADS") {
        if let Ok(n) = s.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// A logical thread pool: just a thread count; workers are scoped per call
/// (creation cost is ~10 µs/thread, negligible against the multi-ms block
/// workloads, and scoping keeps lifetimes simple and safe).
#[derive(Clone, Copy, Debug)]
pub struct ThreadPool {
    pub threads: usize,
}

impl ThreadPool {
    pub fn new(threads: usize) -> Self {
        ThreadPool {
            threads: threads.max(1),
        }
    }

    pub fn with_default() -> Self {
        ThreadPool::new(default_threads())
    }

    /// `threads` workers, with the crate-wide convention that 0 means the
    /// machine default (`ThreadPool::new(0)` alone would mean 1 thread).
    pub fn new_or_default(threads: usize) -> Self {
        if threads == 0 {
            ThreadPool::with_default()
        } else {
            ThreadPool::new(threads)
        }
    }

    /// Dynamically balanced parallel for: `f(i)` for every `i` in
    /// `0..n`, chunks of `chunk` indices claimed atomically.
    ///
    /// `f` must be safe to call concurrently for distinct `i` (callers
    /// ensure disjoint writes; see `spmv::multilevel` for the ownership
    /// discipline).
    pub fn for_each_chunked<F>(&self, n: usize, chunk: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        self.for_each_chunked_worker(n, chunk, |_, i| f(i));
    }

    /// [`Self::for_each_chunked`] with the worker ordinal passed through:
    /// `f(w, i)` with `w < self.threads`, and each `w` running on exactly
    /// one OS thread at a time — so `w` can index per-worker scratch slots
    /// without cross-worker contention (the engine's reusable kernel
    /// buffers).  Serial fallback (1 thread, or `n <= chunk`) uses `w = 0`.
    pub fn for_each_chunked_worker<F>(&self, n: usize, chunk: usize, f: F)
    where
        F: Fn(usize, usize) + Sync,
    {
        if self.threads == 1 || n <= chunk {
            for i in 0..n {
                f(0, i);
            }
            return;
        }
        let next = AtomicUsize::new(0);
        let chunk = chunk.max(1);
        let panicked = PanicSlot::new();
        std::thread::scope(|s| {
            for w in 0..self.threads {
                let fr = &f;
                let nr = &next;
                let pr = &panicked;
                s.spawn(move || {
                    // Bind this OS thread to its worker slot so spans it
                    // records land in the right per-worker slab.
                    crate::obs::set_worker(w);
                    loop {
                        let start = nr.fetch_add(chunk, Ordering::Relaxed);
                        if start >= n {
                            break;
                        }
                        let end = (start + chunk).min(n);
                        for i in start..end {
                            // Contain per-index panics: siblings and the rest
                            // of this worker's chunks still run to completion.
                            if let Err(p) =
                                std::panic::catch_unwind(AssertUnwindSafe(|| fr(w, i)))
                            {
                                pr.record(p);
                            }
                        }
                    }
                });
            }
        });
        panicked.resume();
    }

    /// Parallel map over a slice into a new Vec (order preserved).
    pub fn map<T: Sync, U: Send + Default + Clone, F>(&self, xs: &[T], f: F) -> Vec<U>
    where
        F: Fn(&T) -> U + Sync,
    {
        let mut out = vec![U::default(); xs.len()];
        {
            let slots: Vec<std::sync::Mutex<&mut U>> =
                out.iter_mut().map(std::sync::Mutex::new).collect();
            self.for_each_chunked(xs.len(), 8, |i| {
                **slots[i]
                    .lock()
                    .expect("par.pool map: result-slot mutex poisoned by a contained worker panic") =
                    f(&xs[i]);
            });
        }
        out
    }
}

/// Free-function parallel for over `0..n` with static chunking:
/// the range is split into `threads` contiguous spans, one per worker.
/// Use when per-index cost is uniform (e.g. row-parallel CSR SpMV).
pub fn parallel_for<F>(threads: usize, n: usize, f: F)
where
    F: Fn(std::ops::Range<usize>) + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads == 1 {
        f(0..n);
        return;
    }
    let per = n.div_ceil(threads);
    let panicked = PanicSlot::new();
    std::thread::scope(|s| {
        for t in 0..threads {
            let lo = t * per;
            let hi = ((t + 1) * per).min(n);
            if lo >= hi {
                break;
            }
            let fr = &f;
            let pr = &panicked;
            s.spawn(move || {
                crate::obs::set_worker(t);
                if let Err(p) = std::panic::catch_unwind(AssertUnwindSafe(|| fr(lo..hi))) {
                    pr.record(p);
                }
            });
        }
    });
    panicked.resume();
}

/// Parallel iteration over mutable, disjoint chunks of a slice:
/// `f(chunk_index, chunk)` with `chunk = &mut data[i*size..(i+1)*size]`.
pub fn parallel_chunks<T: Send, F>(threads: usize, data: &mut [T], size: usize, f: F)
where
    F: Fn(usize, &mut [T]) + Sync,
{
    let size = size.max(1);
    if threads <= 1 {
        for (i, c) in data.chunks_mut(size).enumerate() {
            f(i, c);
        }
        return;
    }
    let chunks: Vec<(usize, &mut [T])> = data.chunks_mut(size).enumerate().collect();
    let next = AtomicUsize::new(0);
    let slots: Vec<std::sync::Mutex<Option<(usize, &mut [T])>>> =
        chunks.into_iter().map(|c| std::sync::Mutex::new(Some(c))).collect();
    let panicked = PanicSlot::new();
    std::thread::scope(|s| {
        for w in 0..threads {
            let fr = &f;
            let nr = &next;
            let sl = &slots;
            let pr = &panicked;
            s.spawn(move || {
                crate::obs::set_worker(w);
                loop {
                    let i = nr.fetch_add(1, Ordering::Relaxed);
                    if i >= sl.len() {
                        break;
                    }
                    let (ci, chunk) = sl[i]
                        .lock()
                        .expect("par.pool parallel_chunks: chunk-slot mutex poisoned")
                        .take()
                        .expect(
                            "par.pool parallel_chunks: chunk claimed twice — \
                             atomic index handed out a duplicate",
                        );
                    if let Err(p) =
                        std::panic::catch_unwind(AssertUnwindSafe(|| fr(ci, chunk)))
                    {
                        pr.record(p);
                    }
                }
            });
        }
    });
    panicked.resume();
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn for_each_chunked_visits_all_once() {
        let n = 10_007;
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        ThreadPool::new(8).for_each_chunked(n, 64, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn single_thread_fallback() {
        let n = 100;
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        ThreadPool::new(1).for_each_chunked(n, 7, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn worker_ids_stay_in_range_and_visit_all() {
        let n = 4093;
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        let pool = ThreadPool::new(4);
        pool.for_each_chunked_worker(n, 16, |w, i| {
            assert!(w < pool.threads);
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
        // serial fallback pins worker 0
        ThreadPool::new(1).for_each_chunked_worker(10, 4, |w, _| assert_eq!(w, 0));
    }

    #[test]
    fn parallel_for_covers_range() {
        let n = 5000;
        let acc = AtomicU64::new(0);
        parallel_for(4, n, |r| {
            let mut local = 0u64;
            for i in r {
                local += i as u64;
            }
            acc.fetch_add(local, Ordering::Relaxed);
        });
        assert_eq!(acc.load(Ordering::Relaxed), (n as u64 - 1) * n as u64 / 2);
    }

    #[test]
    fn parallel_chunks_disjoint_writes() {
        let mut v = vec![0u32; 1000];
        parallel_chunks(4, &mut v, 33, |ci, c| {
            for x in c.iter_mut() {
                *x = ci as u32 + 1;
            }
        });
        assert!(v.iter().all(|&x| x > 0));
        assert_eq!(v[0], 1);
        assert_eq!(v[999], 1000usize.div_ceil(33) as u32);
    }

    #[test]
    fn pool_map_preserves_order() {
        let xs: Vec<usize> = (0..500).collect();
        let ys = ThreadPool::new(4).map(&xs, |&x| x * 2);
        assert!(ys.iter().enumerate().all(|(i, &y)| y == i * 2));
    }

    #[test]
    fn default_threads_positive() {
        assert!(default_threads() >= 1);
    }

    #[test]
    fn panic_is_contained_then_reraised_once() {
        let n = 1024;
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        crate::serve::faults::quiet_injected_panics();
        let res = std::panic::catch_unwind(AssertUnwindSafe(|| {
            ThreadPool::new(4).for_each_chunked(n, 16, |i| {
                if i == 500 {
                    panic!("injected");
                }
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
        }));
        // The panic surfaces to the caller exactly once...
        let payload = res.expect_err("contained panic must be re-raised");
        assert_eq!(payload.downcast_ref::<&str>(), Some(&"injected"));
        // ...but every other index still ran: no sibling work was lost.
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), u64::from(i != 500), "index {i}");
        }
    }
}
