//! Far-field apply: `Y += Σ_blocks U·(Vᵀ·X)` under target-leaf ownership.
//!
//! Every far block's rows are exactly one target cut leaf
//! (`hmat::admissible` splits on emission), so the apply reuses the near
//! side's parallel discipline verbatim: one task per non-empty target
//! leaf owns all writes to that leaf's output rows, per-leaf block order
//! is fixed, and therefore the result is **bit-identical across thread
//! counts** within a kernel dispatch.  Both GEMMs of a low-rank block —
//! the `rank x cols` projection `Z = Vᵀ·X` and the `rows x rank`
//! expansion `Y += U·Z` — run through the same `csb::kernel` granules as
//! the near blocks: the scalar reference on the row-major factors, or the
//! AVX2 panel kernel on the packed panels (`hmat::store`).  `Z` lives in
//! a per-worker aligned scratch slot, so steady-state applies allocate
//! nothing once the high-water mark is reached.

use crate::csb::kernel::{self, dense_gemm_acc, Dispatch};
use crate::csb::panel::AlignedF32;
use crate::hmat::store::{FarField, FarKind};
use crate::obs::{self, counters, Counter};
use crate::par::pool::{SendPtr, ThreadPool};
use std::sync::Mutex;

/// Per-worker scratch slots for the `Vᵀ·X` intermediate (one per pool
/// worker; worker `w` locks slot `w` only, so the locks are uncontended).
pub fn worker_scratch(threads: usize) -> Vec<Mutex<AlignedF32>> {
    (0..threads.max(1)).map(|_| Mutex::new(AlignedF32::default())).collect()
}

impl FarField {
    /// `y += far · x` with `k` RHS columns (`x`: `cols x k`, `y`:
    /// `rows x k`, row-major).  **Accumulates** — the caller runs the
    /// near-field apply first (which overwrites `y`) and this adds the
    /// far field on top.  `scratch` must hold at least `pool.threads`
    /// slots ([`worker_scratch`]).
    pub fn apply_acc(
        &self,
        x: &[f32],
        k: usize,
        y: &mut [f32],
        pool: &ThreadPool,
        dispatch: Dispatch,
        scratch: &[Mutex<AlignedF32>],
    ) {
        assert!(k >= 1, "apply needs at least one RHS column");
        assert_eq!(x.len(), self.cols * k);
        assert_eq!(y.len(), self.rows * k);
        assert!(
            scratch.len() >= pool.threads,
            "need one scratch slot per pool worker"
        );
        if self.blocks.is_empty() {
            return;
        }
        obs::span!("hmat.far.apply");
        counters::add(Counter::FarApplyCalls, 1);
        // Compressed multiply-add cells: r·(rn+cn) per low-rank block,
        // rn·cn per dense fallback — flops = 2·cells·k, same convention
        // as `ApplySchedule::flops`.
        let cells: u64 = self
            .blocks
            .iter()
            .map(|b| match b.kind {
                FarKind::LowRank { .. } => b.rank as u64 * (b.rows.len() + b.cols.len()) as u64,
                FarKind::Dense { .. } => b.area(),
            })
            .sum();
        counters::add(Counter::FarGemmFlops, 2 * cells * k as u64);
        let yp = SendPtr(y.as_mut_ptr());
        let ypr = &yp;
        pool.for_each_chunked_worker(self.tasks.len(), 1, |w, ti| {
            obs::span!("hmat.far.task");
            let tl = self.tasks[ti] as usize;
            let sp = self.tgt_leaves[tl];
            // SAFETY: target-leaf row spans are disjoint and each leaf is
            // owned by exactly one task; the slice covers only that span.
            let seg: &mut [f32] = unsafe {
                std::slice::from_raw_parts_mut(ypr.0.add(sp.lo as usize * k), sp.len() * k)
            };
            let mut z = scratch[w].lock().unwrap();
            for &t in &self.by_target[tl] {
                let b = &self.blocks[t as usize];
                debug_assert_eq!(b.rows, sp, "far block must span its target leaf");
                let rn = b.rows.len();
                let cn = b.cols.len();
                let x_seg = &x[b.cols.lo as usize * k..b.cols.hi as usize * k];
                match b.kind {
                    FarKind::LowRank {
                        u_off,
                        vt_off,
                        u_poff,
                        vt_poff,
                    } => {
                        let r = b.rank as usize;
                        if r == 0 {
                            continue; // numerically zero block
                        }
                        let zb = z.reset_zeroed(r * k);
                        far_gemm(
                            dispatch,
                            &self.factors[vt_off as usize..vt_off as usize + r * cn],
                            self.panel(vt_poff, r, cn),
                            r,
                            cn,
                            x_seg,
                            k,
                            zb,
                        );
                        far_gemm(
                            dispatch,
                            &self.factors[u_off as usize..u_off as usize + rn * r],
                            self.panel(u_poff, rn, r),
                            rn,
                            r,
                            zb,
                            k,
                            seg,
                        );
                    }
                    FarKind::Dense { off, poff } => {
                        far_gemm(
                            dispatch,
                            &self.factors[off as usize..off as usize + rn * cn],
                            self.panel(poff, rn, cn),
                            rn,
                            cn,
                            x_seg,
                            k,
                            seg,
                        );
                    }
                }
            }
        });
    }

    #[inline]
    fn panel(&self, poff: u32, nr: usize, nc: usize) -> &[f32] {
        let off = poff as usize;
        &self.panels.as_slice()[off..off + crate::csb::panel::panel_len(nr, nc)]
    }
}

/// One dispatched dense GEMM `y += d · x` over a far factor: the scalar
/// path consumes the row-major values, the AVX2 path the packed panel.
/// Same CPU re-verification guard as `HierCsb::block_matmul_seg_avx2` —
/// a hand-built `Dispatch::Avx2` can never reach the `#[target_feature]`
/// kernel on an unsupported CPU.
#[cfg(target_arch = "x86_64")]
#[allow(clippy::too_many_arguments)]
pub(crate) fn far_gemm(
    dispatch: Dispatch,
    d: &[f32],
    panel: &[f32],
    rn: usize,
    cn: usize,
    x: &[f32],
    k: usize,
    y: &mut [f32],
) {
    if dispatch == Dispatch::Avx2 && kernel::detect() == Dispatch::Avx2 {
        // SAFETY: detect() confirmed AVX2+FMA.
        unsafe { kernel::avx2::panel_gemm_acc(panel, rn, cn, x, k, y) };
        return;
    }
    dense_gemm_acc(d, rn, cn, x, k, y);
}

#[cfg(not(target_arch = "x86_64"))]
#[allow(clippy::too_many_arguments)]
pub(crate) fn far_gemm(
    _dispatch: Dispatch,
    d: &[f32],
    _panel: &[f32],
    rn: usize,
    cn: usize,
    x: &[f32],
    k: usize,
    y: &mut [f32],
) {
    dense_gemm_acc(d, rn, cn, x, k, y);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;
    use crate::hmat::aca::GaussGen;
    use crate::hmat::admissible::partition;
    use crate::tree::boxtree::BoxTree;
    use crate::util::rng::Rng;

    fn setup(n: usize, tol: f32) -> (Vec<f32>, crate::hmat::admissible::Partition, FarField) {
        let ds = SynthSpec::blobs(n, 3, 4, 13).generate();
        let tree = BoxTree::build(&ds, 8, 24);
        let coords = ds.permuted(&tree.perm).raw().to_vec();
        let part = partition(&tree, 32, 1.0);
        let far = FarField::build(&part, &coords, 3, 0.6, tol, 2);
        (coords, part, far)
    }

    /// f64 oracle of the far field alone: sum the exact Gaussian over the
    /// partition's far rectangles.
    fn far_oracle(
        coords: &[f32],
        part: &crate::hmat::admissible::Partition,
        x: &[f32],
    ) -> Vec<f64> {
        let gen = GaussGen {
            coords,
            d: 3,
            inv_h2: 0.6,
        };
        let mut y = vec![0.0f64; part.n];
        for fb in &part.far {
            for i in fb.rows.lo..fb.rows.hi {
                let mut acc = 0.0f64;
                for j in fb.cols.lo..fb.cols.hi {
                    acc += gen.entry_f64(i as usize, j as usize) * x[j as usize] as f64;
                }
                y[i as usize] += acc;
            }
        }
        y
    }

    #[test]
    fn far_apply_matches_f64_oracle() {
        let tol = 1e-3f32;
        let (coords, part, far) = setup(700, tol);
        assert!(!far.is_empty(), "test needs far blocks");
        let mut rng = Rng::new(7);
        let x: Vec<f32> = (0..700).map(|_| rng.f32() - 0.5).collect();
        let want = far_oracle(&coords, &part, &x);
        let pool = ThreadPool::new(2);
        let scratch = worker_scratch(pool.threads);
        let mut y = vec![0.0f32; 700];
        far.apply_acc(&x, 1, &mut y, &pool, Dispatch::Scalar, &scratch);
        let norm: f64 = want.iter().map(|w| w * w).sum::<f64>().sqrt();
        let err: f64 = y
            .iter()
            .zip(&want)
            .map(|(&g, &w)| (g as f64 - w) * (g as f64 - w))
            .sum::<f64>()
            .sqrt();
        assert!(
            err <= 10.0 * tol as f64 * norm + 1e-12,
            "far apply err {err} vs norm {norm} ({})",
            far.describe()
        );
    }

    #[test]
    fn far_apply_accumulates_and_is_thread_invariant() {
        let (_, _, far) = setup(600, 1e-3);
        let mut rng = Rng::new(11);
        let x: Vec<f32> = (0..600).map(|_| rng.f32()).collect();
        let base: Vec<f32> = (0..600).map(|_| rng.f32()).collect();
        let mut reference: Vec<f32> = Vec::new();
        for threads in [1usize, 2, 8] {
            let pool = ThreadPool::new(threads);
            let scratch = worker_scratch(pool.threads);
            let mut y = base.clone();
            far.apply_acc(&x, 1, &mut y, &pool, Dispatch::Scalar, &scratch);
            // accumulation: y - base is the far product, base survives
            assert!(y.iter().zip(&base).any(|(a, b)| a != b), "apply was a no-op");
            if reference.is_empty() {
                reference = y;
            } else {
                assert!(
                    y.iter().zip(&reference).all(|(a, b)| a.to_bits() == b.to_bits()),
                    "thread-count bit-identity violated at threads={threads}"
                );
            }
        }
    }

    #[test]
    fn multi_rhs_columns_bitexact_with_single_rhs() {
        // Same chain-per-column argument as HierCsb::block_matmul: every
        // spmm column must reproduce the k=1 apply bit-for-bit (scalar).
        let (_, _, far) = setup(500, 1e-3);
        let n = 500;
        let mut rng = Rng::new(23);
        let k = 5;
        let x: Vec<f32> = (0..n * k).map(|_| rng.f32() - 0.5).collect();
        let pool = ThreadPool::new(2);
        let scratch = worker_scratch(pool.threads);
        let mut y = vec![0.0f32; n * k];
        far.apply_acc(&x, k, &mut y, &pool, Dispatch::Scalar, &scratch);
        for j in 0..k {
            let xj: Vec<f32> = (0..n).map(|i| x[i * k + j]).collect();
            let mut yj = vec![0.0f32; n];
            far.apply_acc(&xj, 1, &mut yj, &pool, Dispatch::Scalar, &scratch);
            for i in 0..n {
                assert_eq!(
                    y[i * k + j].to_bits(),
                    yj[i].to_bits(),
                    "col {j} row {i} differs from k=1"
                );
            }
        }
    }

    #[test]
    fn dispatched_apply_matches_scalar_within_tolerance() {
        let (_, _, far) = setup(500, 1e-3);
        let n = 500;
        let mut rng = Rng::new(29);
        for k in [1usize, 3, 8] {
            let x: Vec<f32> = (0..n * k).map(|_| rng.f32() - 0.5).collect();
            let pool = ThreadPool::new(2);
            let scratch = worker_scratch(pool.threads);
            let mut y_ref = vec![0.0f32; n * k];
            far.apply_acc(&x, k, &mut y_ref, &pool, Dispatch::Scalar, &scratch);
            let (d, _) = crate::csb::kernel::KernelKind::Auto.resolve();
            let mut y = vec![0.0f32; n * k];
            far.apply_acc(&x, k, &mut y, &pool, d, &scratch);
            for (g, w) in y.iter().zip(&y_ref) {
                assert!((g - w).abs() < 1e-5 * (1.0 + w.abs()), "k={k}: {g} vs {w}");
            }
        }
    }

    #[test]
    fn empty_far_field_is_a_noop() {
        let ds = SynthSpec::blobs(200, 2, 3, 3).generate();
        let tree = BoxTree::build(&ds, 8, 24);
        let part = partition(&tree, 32, 1.0);
        let far = FarField::empty(&part, 1e-3);
        let pool = ThreadPool::new(2);
        let scratch = worker_scratch(pool.threads);
        let x = vec![1.0f32; 200];
        let mut y = vec![2.5f32; 200];
        far.apply_acc(&x, 1, &mut y, &pool, Dispatch::Scalar, &scratch);
        assert!(y.iter().all(|&v| v == 2.5));
    }
}
