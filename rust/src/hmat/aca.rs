//! Adaptive cross approximation (ACA) of admissible Gaussian blocks.
//!
//! A far block `A` (entries `exp(−‖t_i − s_j‖²·inv_h2)` over a pair of
//! well-separated boxes) is numerically low-rank; partial-pivot ACA
//! builds a rank-`r` factorization `A ≈ U·Vᵀ` from `O(r)` generated rows
//! and columns without ever materializing the block:
//!
//! 1. generate the residual row at the current pivot row, pick the pivot
//!    column as its largest unused entry, scale the row into `v_r`;
//! 2. generate the residual column at the pivot column — that is `u_r`;
//! 3. update the running estimate of `‖U·Vᵀ‖_F` incrementally and stop
//!    once the last increment `‖u_r‖·‖v_r‖` drops below
//!    `ACA_SAFETY · tol · ‖U·Vᵀ‖_F` (the safety factor absorbs the tail
//!    the last-increment heuristic does not see, so the *contract* —
//!    relative Frobenius reconstruction error ≤ `tol` against an f64
//!    dense oracle — holds with margin; property-tested in
//!    `rust/tests/prop_invariants.rs`);
//! 4. the next pivot row is the largest unused entry of `u_r`.
//!
//! **Dense fallback**: if the rank reaches half the smaller block side,
//! the factorization has lost against dense storage
//! (`(rn+cn)·r ≥ rn·cn` around `r = min/2` for squarish blocks) — the
//! block is regenerated dense and stored verbatim, which also makes the
//! ≤ tol contract exact (up to f32 rounding) on blocks the admissibility
//! heuristic misjudged.
//!
//! Everything is sequential and a pure function of (coords, spans, tol):
//! factorizing blocks in parallel stays bit-deterministic.

use crate::csb::hier::Span;

/// Entry generator for the Gaussian kernel over tree-ordered coordinates
/// (`coords`: row-major `n x d`): `A[i,j] = exp(−‖x_i − x_j‖²·inv_h2)`.
#[derive(Clone, Copy, Debug)]
pub struct GaussGen<'a> {
    pub coords: &'a [f32],
    pub d: usize,
    pub inv_h2: f32,
}

impl<'a> GaussGen<'a> {
    #[inline]
    pub fn entry(&self, i: usize, j: usize) -> f32 {
        let a = &self.coords[i * self.d..(i + 1) * self.d];
        let b = &self.coords[j * self.d..(j + 1) * self.d];
        let mut d2 = 0.0f32;
        for (p, q) in a.iter().zip(b) {
            let t = p - q;
            d2 += t * t;
        }
        (-d2 * self.inv_h2).exp()
    }

    /// The same entry evaluated in f64 (test oracles).
    pub fn entry_f64(&self, i: usize, j: usize) -> f64 {
        let a = &self.coords[i * self.d..(i + 1) * self.d];
        let b = &self.coords[j * self.d..(j + 1) * self.d];
        let mut d2 = 0.0f64;
        for (p, q) in a.iter().zip(b) {
            let t = *p as f64 - *q as f64;
            d2 += t * t;
        }
        (-d2 * self.inv_h2 as f64).exp()
    }
}

/// One block's factorization.
#[derive(Clone, Debug, PartialEq)]
pub enum AcaFactor {
    /// `A ≈ U·Vᵀ` with `U` row-major `rows x rank` and `Vt` row-major
    /// `rank x cols`.  `rank == 0` means the block is numerically zero at
    /// f32 resolution (every generated pivot row vanished).
    LowRank {
        u: Vec<f32>,
        vt: Vec<f32>,
        rank: usize,
    },
    /// Dense fallback: the block's values, row-major `rows x cols`.
    Dense(Vec<f32>),
}

impl Default for AcaFactor {
    fn default() -> Self {
        AcaFactor::LowRank {
            u: Vec::new(),
            vt: Vec::new(),
            rank: 0,
        }
    }
}

impl AcaFactor {
    /// Stored f32 count (storage accounting).
    pub fn stored_len(&self) -> usize {
        match self {
            AcaFactor::LowRank { u, vt, .. } => u.len() + vt.len(),
            AcaFactor::Dense(v) => v.len(),
        }
    }
}

/// Safety factor on the ACA stopping criterion (see module docs).
pub const ACA_SAFETY: f32 = 0.25;

/// A successful ACA run with the accepted pivots recorded: the raw
/// column-stacked factors plus the block-local pivot rows/columns in
/// acceptance order.  The pivots are the block's *skeleton* — the H²
/// basis construction ([`crate::hmat::h2`]) interpolates cluster bases
/// through them.
#[derive(Clone, Debug, Default, PartialEq)]
pub(crate) struct AcaBuild {
    /// Column-stacked `U`: `us[k*rn..(k+1)*rn]` is the k-th column.
    pub us: Vec<f32>,
    /// Row-major `Vᵀ`: `vs[k*cn..(k+1)*cn]` is the k-th row.
    pub vs: Vec<f32>,
    pub rank: usize,
    /// Accepted pivot rows (block-local), one per rank step.
    pub row_piv: Vec<u32>,
    /// Accepted pivot columns (block-local), one per rank step.
    pub col_piv: Vec<u32>,
}

/// The partial-pivot ACA core loop over an arbitrary entry generator
/// (`entry(i, j)` with block-local indices).  Returns `None` when the
/// rank reaches half the smaller side — the caller's dense-fallback
/// signal.  The arithmetic is identical to [`aca_gauss`]'s historical
/// inline loop, so factors stay bit-for-bit reproducible.
pub(crate) fn aca_core<F: Fn(usize, usize) -> f32>(
    entry: F,
    rn: usize,
    cn: usize,
    tol: f32,
) -> Option<AcaBuild> {
    assert!(tol > 0.0 && tol.is_finite(), "aca tolerance must be positive");
    let max_rank = rn.min(cn) / 2;

    // u_k / v_k stored contiguously per rank step: `us[k*rn..]` is the
    // k-th column of U, `vs[k*cn..]` the k-th row of Vᵀ (already the
    // row-major Vt layout the apply consumes).
    let mut us: Vec<f32> = Vec::new();
    let mut vs: Vec<f32> = Vec::new();
    let mut rank = 0usize;
    let mut row_used = vec![false; rn];
    let mut col_used = vec![false; cn];
    let mut row_piv: Vec<u32> = Vec::new();
    let mut col_piv: Vec<u32> = Vec::new();
    // ‖U·Vᵀ‖_F² maintained incrementally in f64.
    let mut est2 = 0.0f64;
    let mut piv_row = 0usize;
    // Consecutive below-threshold increments: stopping only after two in
    // a row guards against a single accidentally small pivot step hiding
    // a fat residual tail.
    let mut small_streak = 0usize;

    loop {
        if rank >= max_rank {
            // Rank would exceed half the block side: dense wins.
            return None;
        }
        // Residual row at piv_row: A[piv_row, :] − Σ_k u_k[piv_row]·v_k.
        let mut r: Vec<f32> = (0..cn).map(|j| entry(piv_row, j)).collect();
        for k in 0..rank {
            let uk = us[k * rn + piv_row];
            if uk != 0.0 {
                for (rv, &vv) in r.iter_mut().zip(&vs[k * cn..(k + 1) * cn]) {
                    *rv -= uk * vv;
                }
            }
        }
        row_used[piv_row] = true;
        // Pivot column: largest residual magnitude among unused columns.
        let mut piv_col = usize::MAX;
        let mut piv_abs = 0.0f32;
        for (j, &rv) in r.iter().enumerate() {
            if !col_used[j] && rv.abs() > piv_abs {
                piv_abs = rv.abs();
                piv_col = j;
            }
        }
        if piv_col == usize::MAX || piv_abs < f32::MIN_POSITIVE {
            // Numerically zero residual row — try the next unused row, or
            // accept the current factorization if none remain.
            match row_used.iter().position(|&u| !u) {
                Some(i) => {
                    piv_row = i;
                    continue;
                }
                None => break,
            }
        }
        let piv = r[piv_col];
        let inv = 1.0f32 / piv;
        for rv in r.iter_mut() {
            *rv *= inv;
        }
        col_used[piv_col] = true;
        row_piv.push(piv_row as u32);
        col_piv.push(piv_col as u32);
        // Residual column at piv_col: A[:, piv_col] − Σ_k v_k[piv_col]·u_k.
        let mut c: Vec<f32> = (0..rn).map(|i| entry(i, piv_col)).collect();
        for k in 0..rank {
            let vk = vs[k * cn + piv_col];
            if vk != 0.0 {
                for (cv, &uv) in c.iter_mut().zip(&us[k * rn..(k + 1) * rn]) {
                    *cv -= vk * uv;
                }
            }
        }
        // Norm bookkeeping (f64): ‖Ã + u·vᵀ‖² = ‖Ã‖² + ‖u‖²‖v‖²
        //                                       + 2·Σ_k (u_k·u)(v_k·v).
        let nu2 = dot64(&c, &c);
        let nv2 = dot64(&r, &r);
        let mut cross = 0.0f64;
        for k in 0..rank {
            cross += dot64(&us[k * rn..(k + 1) * rn], &c) * dot64(&vs[k * cn..(k + 1) * cn], &r);
        }
        est2 = (est2 + nu2 * nv2 + 2.0 * cross).max(0.0);
        us.extend_from_slice(&c);
        vs.extend_from_slice(&r);
        rank += 1;
        let inc = (nu2 * nv2).sqrt();
        if est2 > 0.0 && inc <= (ACA_SAFETY * tol) as f64 * est2.sqrt() {
            small_streak += 1;
            if small_streak >= 2 {
                break;
            }
        } else {
            small_streak = 0;
        }
        // Next pivot row: largest magnitude of the new column among
        // unused rows.
        let mut best = usize::MAX;
        let mut best_abs = -1.0f32;
        for (i, &cv) in c.iter().enumerate() {
            if !row_used[i] && cv.abs() > best_abs {
                best_abs = cv.abs();
                best = i;
            }
        }
        match best {
            usize::MAX => break,
            i => piv_row = i,
        }
    }

    Some(AcaBuild {
        us,
        vs,
        rank,
        row_piv,
        col_piv,
    })
}

/// Factorize the `rows x cols` Gaussian block to relative Frobenius
/// tolerance `tol`, falling back to dense storage when the rank would
/// exceed half the smaller block side.
pub fn aca_gauss(gen: &GaussGen, rows: Span, cols: Span, tol: f32) -> AcaFactor {
    let rn = rows.len();
    let cn = cols.len();
    if rn == 0 || cn == 0 {
        assert!(tol > 0.0 && tol.is_finite(), "aca tolerance must be positive");
        return AcaFactor::default();
    }
    let r0 = rows.lo as usize;
    let c0 = cols.lo as usize;
    match aca_core(|i, j| gen.entry(r0 + i, c0 + j), rn, cn, tol) {
        None => AcaFactor::Dense(dense_fill(gen, rows, cols)),
        Some(b) => {
            // Transpose the column-stacked `us` into row-major `U`
            // (`rn x rank`); `vs` already is row-major `Vt` (`rank x cn`).
            let mut u = vec![0.0f32; rn * b.rank];
            for k in 0..b.rank {
                for i in 0..rn {
                    u[i * b.rank + k] = b.us[k * rn + i];
                }
            }
            AcaFactor::LowRank {
                u,
                vt: b.vs,
                rank: b.rank,
            }
        }
    }
}

/// Generate the full block row-major (the dense fallback and test oracle
/// at f32 precision).
pub fn dense_fill(gen: &GaussGen, rows: Span, cols: Span) -> Vec<f32> {
    let rn = rows.len();
    let cn = cols.len();
    let mut out = vec![0.0f32; rn * cn];
    for i in 0..rn {
        let row = &mut out[i * cn..(i + 1) * cn];
        for (j, v) in row.iter_mut().enumerate() {
            *v = gen.entry(rows.lo as usize + i, cols.lo as usize + j);
        }
    }
    out
}

/// f32 dot product with f64 accumulation — the scalar-accumulation
/// precision discipline shared by the ACA norm bookkeeping and the KRR
/// CG ([`crate::apps::krr`]).
#[inline]
pub fn dot64(a: &[f32], b: &[f32]) -> f64 {
    a.iter().zip(b).map(|(&x, &y)| x as f64 * y as f64).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Two anisotropic clusters `gap` apart along axis 0; rows = first
    /// cluster, cols = second.
    fn two_clusters(rng: &mut Rng, rn: usize, cn: usize, d: usize, gap: f32) -> Vec<f32> {
        let mut coords = Vec::with_capacity((rn + cn) * d);
        let scales: Vec<f32> = (0..d).map(|_| 0.05 + 0.3 * rng.f32()).collect();
        for i in 0..rn + cn {
            for (a, &s) in scales.iter().enumerate() {
                let mut v = s * rng.normal() as f32;
                if i >= rn && a == 0 {
                    v += gap;
                }
                coords.push(v);
            }
        }
        coords
    }

    fn rel_frob_err(gen: &GaussGen, rows: Span, cols: Span, f: &AcaFactor) -> (f64, f64) {
        let rn = rows.len();
        let cn = cols.len();
        let mut err2 = 0.0f64;
        let mut norm2 = 0.0f64;
        for i in 0..rn {
            for j in 0..cn {
                let a = gen.entry_f64(rows.lo as usize + i, cols.lo as usize + j);
                let approx = match f {
                    AcaFactor::LowRank { u, vt, rank } => (0..*rank)
                        .map(|k| u[i * rank + k] as f64 * vt[k * cn + j] as f64)
                        .sum::<f64>(),
                    AcaFactor::Dense(v) => v[i * cn + j] as f64,
                };
                err2 += (a - approx) * (a - approx);
                norm2 += a * a;
            }
        }
        (err2.sqrt(), norm2.sqrt())
    }

    #[test]
    fn separated_clusters_compress_to_low_rank() {
        let mut rng = Rng::new(17);
        let coords = two_clusters(&mut rng, 48, 40, 3, 4.0);
        let gen = GaussGen {
            coords: &coords,
            d: 3,
            inv_h2: 0.5,
        };
        let rows = Span { lo: 0, hi: 48 };
        let cols = Span { lo: 48, hi: 88 };
        let f = aca_gauss(&gen, rows, cols, 1e-3);
        let AcaFactor::LowRank { rank, .. } = &f else {
            panic!("well-separated block must stay low-rank");
        };
        assert!(*rank < 20, "rank {rank} too high for a separated pair");
        let (err, norm) = rel_frob_err(&gen, rows, cols, &f);
        assert!(err <= 1e-3 * norm + 1e-20, "err {err} vs tol*norm {}", 1e-3 * norm);
    }

    #[test]
    fn overlapping_clusters_fall_back_to_dense() {
        // gap 0 → the block is essentially full-rank; ACA must bail to
        // dense and the stored values are exact at f32 resolution.
        let mut rng = Rng::new(5);
        let coords = two_clusters(&mut rng, 24, 24, 2, 0.0);
        let gen = GaussGen {
            coords: &coords,
            d: 2,
            inv_h2: 40.0,
        };
        let rows = Span { lo: 0, hi: 24 };
        let cols = Span { lo: 24, hi: 48 };
        let f = aca_gauss(&gen, rows, cols, 1e-4);
        let (err, norm) = rel_frob_err(&gen, rows, cols, &f);
        assert!(err <= 1e-4 * norm + 1e-20, "err {err} norm {norm}");
        if let AcaFactor::LowRank { rank, .. } = &f {
            assert!(*rank <= 12, "rank cap violated: {rank}");
        }
    }

    #[test]
    fn numerically_zero_block_yields_rank_zero() {
        // Clusters so far apart every f32 entry underflows to 0.
        let mut rng = Rng::new(9);
        let coords = two_clusters(&mut rng, 16, 16, 2, 1e4);
        let gen = GaussGen {
            coords: &coords,
            d: 2,
            inv_h2: 1.0,
        };
        let f = aca_gauss(&gen, Span { lo: 0, hi: 16 }, Span { lo: 16, hi: 32 }, 1e-3);
        assert_eq!(
            f,
            AcaFactor::default(),
            "all-zero block must produce the empty factorization"
        );
        assert_eq!(f.stored_len(), 0);
    }

    #[test]
    fn rank_one_block_recovered_exactly() {
        // All targets at one point, all sources at another: A is exactly
        // rank one, ACA must stop at rank 1.
        let mut coords = vec![0.0f32; 40 * 2];
        for i in 20..40 {
            coords[i * 2] = 2.0;
        }
        let gen = GaussGen {
            coords: &coords,
            d: 2,
            inv_h2: 0.3,
        };
        let rows = Span { lo: 0, hi: 20 };
        let cols = Span { lo: 20, hi: 40 };
        let f = aca_gauss(&gen, rows, cols, 1e-3);
        match &f {
            AcaFactor::LowRank { rank, .. } => assert_eq!(*rank, 1),
            AcaFactor::Dense(_) => panic!("rank-1 block must not fall back to dense"),
        }
        let (err, norm) = rel_frob_err(&gen, rows, cols, &f);
        assert!(err <= 1e-6 * norm, "rank-1 recovery err {err} norm {norm}");
    }
}
