//! η-admissibility partition of the interaction index space.
//!
//! A dual-tree traversal over [`BoxTree`] node pairs splits the `n x n`
//! index space into two disjoint families of rectangles:
//!
//! * **near pairs** — (target cut leaf × source cut leaf) rectangles whose
//!   boxes are *not* well separated; the full-kernel engine stores them as
//!   dense `HierCsb` blocks (the existing near-field machinery);
//! * **far blocks** — rectangles whose boxes satisfy the η-admissibility
//!   criterion; `hmat::aca` compresses each into a low-rank factorization.
//!
//! Admissibility is evaluated from the tree's box geometry alone (centers
//! and half-widths — the boxes are cubes, so the enclosing-ball radius is
//! `half·sqrt(d)`): a pair is admissible when the gap between the balls is
//! positive and the smaller diameter is at most `η` times the gap,
//!
//! ```text
//! gap = ‖c_t − c_s‖ − r_t − r_s   (r = half·sqrt(d))
//! admissible ⇔ gap > 0  ∧  2·min(r_t, r_s) ≤ η·gap
//! ```
//!
//! Larger η admits closer pairs (more far-field coverage, higher ranks);
//! η → 0 degenerates to an all-near partition.  The *accuracy* of the
//! compressed operator never depends on η — ACA runs to the requested
//! tolerance on whatever blocks are admitted (with a dense fallback) — η
//! only moves the near/far storage trade-off.
//!
//! Emitted far pairs are split on the target side into one block per
//! **target cut leaf** (the traversal never descends below the size cut,
//! so a far pair's row span is always a union of consecutive cut leaves).
//! Every far block then belongs to exactly one target leaf — the same
//! output-ownership discipline as the near blocks — which is what makes
//! the fused apply deterministic and lock-free (`hmat::apply`).

use crate::csb::hier::{LEAF_POINTS, Span};
use crate::tree::boxtree::BoxTree;

/// One far-field rectangle after target-leaf splitting: `rows` is exactly
/// the span of target cut leaf `tleaf`; `cols` is the span of an
/// admissible source node (possibly far above the cut).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FarBlockSpec {
    pub tleaf: u32,
    pub rows: Span,
    pub cols: Span,
    /// Tree node id of the admissible source node — the identity the
    /// incremental update keys factor reuse on (`hmat::update`).
    pub src_node: u32,
}

/// The admissibility partition of the `n x n` self-interaction index
/// space: near pairs + far blocks tile it exactly (no gaps, no overlap —
/// property-tested in `rust/tests/prop_invariants.rs`).
#[derive(Clone, Debug)]
pub struct Partition {
    /// Number of points (rows = cols of the index space).
    pub n: usize,
    /// Size-cut node ids in span order (`BoxTree::cut_by_size(block_cap)`,
    /// the same cut `HierCsb::build_with_par` derives — the near side and
    /// the far side agree on the leaf blocking by construction).
    pub cut: Vec<u32>,
    /// Cut-leaf spans in order (row *and* column blocking).
    pub leaves: Vec<Span>,
    /// Near (target leaf ordinal, source leaf ordinal) pairs.
    pub near: Vec<(u32, u32)>,
    /// Far blocks, one per (target cut leaf, admissible source node).
    pub far: Vec<FarBlockSpec>,
    /// Admissibility parameter the partition was built with.
    pub eta: f32,
}

impl Partition {
    /// Total index-space area of the near rectangles.
    pub fn near_area(&self) -> u64 {
        self.near
            .iter()
            .map(|&(t, s)| {
                self.leaves[t as usize].len() as u64 * self.leaves[s as usize].len() as u64
            })
            .sum()
    }

    /// Total index-space area of the far rectangles.
    pub fn far_area(&self) -> u64 {
        self.far
            .iter()
            .map(|b| b.rows.len() as u64 * b.cols.len() as u64)
            .sum()
    }
}

/// η-admissibility of a node pair (see module docs).  A node is never
/// admissible with itself (zero gap).
pub fn admissible(tree: &BoxTree, a: u32, b: u32, eta: f32) -> bool {
    if a == b {
        return false;
    }
    let na = &tree.nodes[a as usize];
    let nb = &tree.nodes[b as usize];
    let sd = (tree.d as f32).sqrt();
    let ra = na.half * sd;
    let rb = nb.half * sd;
    let mut dist2 = 0.0f32;
    for (p, q) in na.center.iter().zip(&nb.center) {
        let t = p - q;
        dist2 += t * t;
    }
    let gap = dist2.sqrt() - ra - rb;
    gap > 0.0 && 2.0 * ra.min(rb) <= eta * gap
}

/// Build the admissibility partition over `tree`'s size cut at
/// `block_cap` (0 = [`LEAF_POINTS`], matching `HierCsb::build_with_par`).
///
/// Traversal: descend node pairs from (root, root); an admissible pair is
/// emitted far, a pair of cut members is emitted near, otherwise the side
/// with the larger box splits into its children (each child partitions
/// the parent span, so the emitted rectangles tile the index space by
/// induction).  Fully sequential and a pure function of the tree — the
/// partition is deterministic.
pub fn partition(tree: &BoxTree, block_cap: usize, eta: f32) -> Partition {
    assert!(eta > 0.0 && eta.is_finite(), "eta must be positive");
    let block_cap = if block_cap == 0 { LEAF_POINTS } else { block_cap };
    let n = tree.n();
    let cut = tree.cut_by_size(block_cap);
    let leaves: Vec<Span> = cut
        .iter()
        .map(|&id| Span {
            lo: tree.nodes[id as usize].lo,
            hi: tree.nodes[id as usize].hi,
        })
        .collect();
    let mut ord = vec![u32::MAX; tree.nodes.len()];
    for (o, &id) in cut.iter().enumerate() {
        ord[id as usize] = o as u32;
    }

    let mut near: Vec<(u32, u32)> = Vec::new();
    let mut far_pairs: Vec<(u32, u32)> = Vec::new();
    if n > 0 {
        descend(tree, 0, 0, eta, &ord, &mut near, &mut far_pairs);
    }

    // Split each far pair's row span into its covering cut leaves: the
    // traversal never descends a side below cut membership, so a far
    // node's span is a union of consecutive cut leaves.
    let mut far: Vec<FarBlockSpec> = Vec::new();
    for &(tn, sn) in &far_pairs {
        let t = &tree.nodes[tn as usize];
        let s = &tree.nodes[sn as usize];
        let cols = Span { lo: s.lo, hi: s.hi };
        let first = leaves.partition_point(|sp| sp.lo < t.lo);
        debug_assert!(
            first < leaves.len() && leaves[first].lo == t.lo,
            "far pair row span does not start on a cut boundary"
        );
        let mut o = first;
        let mut covered = t.lo;
        while o < leaves.len() && leaves[o].hi <= t.hi {
            far.push(FarBlockSpec {
                tleaf: o as u32,
                rows: leaves[o],
                cols,
                src_node: sn,
            });
            covered = leaves[o].hi;
            o += 1;
        }
        debug_assert_eq!(covered, t.hi, "far pair row span not covered by cut leaves");
    }

    Partition {
        n,
        cut,
        leaves,
        near,
        far,
        eta,
    }
}

fn descend(
    tree: &BoxTree,
    tn: u32,
    sn: u32,
    eta: f32,
    ord: &[u32],
    near: &mut Vec<(u32, u32)>,
    far: &mut Vec<(u32, u32)>,
) {
    if admissible(tree, tn, sn, eta) {
        far.push((tn, sn));
        return;
    }
    let t_term = ord[tn as usize] != u32::MAX;
    let s_term = ord[sn as usize] != u32::MAX;
    match (t_term, s_term) {
        (true, true) => near.push((ord[tn as usize], ord[sn as usize])),
        (false, true) => {
            for &c in &tree.nodes[tn as usize].children {
                descend(tree, c, sn, eta, ord, near, far);
            }
        }
        (true, false) => {
            for &c in &tree.nodes[sn as usize].children {
                descend(tree, tn, c, eta, ord, near, far);
            }
        }
        (false, false) => {
            // Split the bigger box (ties split the target) so the pair
            // shrinks toward comparable scales — the classic H-matrix
            // descent that keeps admissible blocks squarish.
            if tree.nodes[tn as usize].half >= tree.nodes[sn as usize].half {
                for &c in &tree.nodes[tn as usize].children {
                    descend(tree, c, sn, eta, ord, near, far);
                }
            } else {
                for &c in &tree.nodes[sn as usize].children {
                    descend(tree, tn, c, eta, ord, near, far);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;

    #[test]
    fn partition_tiles_small_instance() {
        let ds = SynthSpec::blobs(300, 3, 4, 7).generate();
        let tree = BoxTree::build(&ds, 8, 24);
        let part = partition(&tree, 32, 1.0);
        assert_eq!(part.n, 300);
        let mut cover = vec![0u8; 300 * 300];
        for &(tl, sl) in &part.near {
            let (r, c) = (part.leaves[tl as usize], part.leaves[sl as usize]);
            for i in r.lo..r.hi {
                for j in c.lo..c.hi {
                    cover[i as usize * 300 + j as usize] += 1;
                }
            }
        }
        for b in &part.far {
            assert_eq!(b.rows, part.leaves[b.tleaf as usize]);
            for i in b.rows.lo..b.rows.hi {
                for j in b.cols.lo..b.cols.hi {
                    cover[i as usize * 300 + j as usize] += 1;
                }
            }
        }
        assert!(cover.iter().all(|&c| c == 1), "partition must tile exactly once");
        assert_eq!(part.near_area() + part.far_area(), 300 * 300);
    }

    #[test]
    fn diagonal_pairs_are_near() {
        let ds = SynthSpec::blobs(200, 2, 3, 5).generate();
        let tree = BoxTree::build(&ds, 8, 24);
        let part = partition(&tree, 16, 1.0);
        for tl in 0..part.leaves.len() as u32 {
            assert!(
                part.near.contains(&(tl, tl)),
                "diagonal block {tl} must be near (a box is never admissible with itself)"
            );
        }
    }

    #[test]
    fn clustered_data_produces_far_field() {
        // Well-separated blobs: cross-cluster rectangles must be admissible.
        let ds = SynthSpec::blobs(600, 3, 4, 11).generate();
        let tree = BoxTree::build(&ds, 8, 24);
        let part = partition(&tree, 32, 1.0);
        assert!(!part.far.is_empty(), "separated clusters must admit far blocks");
        assert!(part.far_area() > 0);
        // far blocks never sit on the diagonal
        for b in &part.far {
            let disjoint = b.rows.hi <= b.cols.lo || b.cols.hi <= b.rows.lo;
            assert!(disjoint, "far block overlaps the diagonal: {b:?}");
        }
    }

    #[test]
    fn eta_monotonicity() {
        // Larger η admits more (or equally many) far entries.
        let ds = SynthSpec::blobs(400, 3, 4, 3).generate();
        let tree = BoxTree::build(&ds, 8, 24);
        let a_small = partition(&tree, 32, 0.25).far_area();
        let a_big = partition(&tree, 32, 2.0).far_area();
        assert!(a_big >= a_small, "eta=2 area {a_big} < eta=0.25 area {a_small}");
    }

    #[test]
    fn admissible_is_symmetric_and_irreflexive() {
        let ds = SynthSpec::blobs(300, 3, 4, 9).generate();
        let tree = BoxTree::build(&ds, 8, 24);
        for a in 0..tree.nodes.len() as u32 {
            assert!(!admissible(&tree, a, a, 1.0));
        }
        for a in (0..tree.nodes.len() as u32).step_by(3) {
            for b in (0..tree.nodes.len() as u32).step_by(5) {
                assert_eq!(
                    admissible(&tree, a, b, 1.0),
                    admissible(&tree, b, a, 1.0),
                    "admissibility must be symmetric ({a},{b})"
                );
            }
        }
    }
}
