//! Incremental full-kernel update: re-run the Gaussian entry generation
//! and the ACA factorizations only for block pairs the tree update
//! actually touched.
//!
//! Both reuse paths lean on determinism and purity:
//!
//! * a **near row** is a concatenation of `exp(−‖x_i − x_j‖²·inv_h2)`
//!   segments over the leaf's near source spans — for a clean target leaf
//!   whose near list maps 1:1 onto its old counterpart, the old values are
//!   bit-equal (same coordinate pairs), so they are copied out of the old
//!   near `HierCsb` dense arena instead of re-running `exp` per entry;
//! * a **far factor** is `aca_gauss(rows, cols)`, a pure sequential
//!   function of the member coordinates — for a (clean cut leaf, clean
//!   source node) pair that the *old* partition also emitted, the old
//!   factor is byte-identical to what refactoring would produce, so it is
//!   lifted from the old arenas.
//!
//! Every reuse decision is cross-checked against the old layout (span
//! lengths, block kinds, list correspondence); any mismatch falls back to
//! regeneration, so the assembled result is bit-identical to a
//! from-scratch build over the new inputs at any thread count.

use crate::csb::hier::{BlockKind, HierCsb};
use crate::csb::update::SideDelta;
use crate::hmat::aca::{aca_gauss, AcaFactor, GaussGen};
use crate::hmat::admissible::Partition;
use crate::hmat::store::{FarField, FarKind};
use crate::obs::{self, counters, Counter};
use crate::par::pool::{SendPtr, ThreadPool};
use crate::sparse::csr::Csr;
use std::collections::HashMap;

/// Old-cut index: tree node id → cut-leaf ordinal.
pub(crate) fn cut_ordinals(part: &Partition) -> HashMap<u32, u32> {
    part.cut
        .iter()
        .enumerate()
        .map(|(o, &id)| (id, o as u32))
        .collect()
}

/// Rebuild the near-field Gaussian profile CSR for `part_new`, copying the
/// rows of reusable target leaves out of `old_csb`'s dense arena (every
/// near block stores dense — density exactly 1.0) and generating the rest.
/// Bit-identical to `near_profile` over the new partition.
#[allow(clippy::too_many_arguments)]
pub(crate) fn near_profile_update(
    part_new: &Partition,
    part_old: &Partition,
    old_csb: &HierCsb,
    coords: &[f32],
    d: usize,
    inv_h2: f32,
    delta: &SideDelta,
    threads: usize,
) -> Csr {
    let n = part_new.n;
    let gen = GaussGen { coords, d, inv_h2 };
    let nt = part_new.leaves.len();

    // Per target leaf: near source ordinals ascending (spans are in leaf
    // order, so ascending ordinal == ascending span).
    let mut near_of: Vec<Vec<u32>> = vec![Vec::new(); nt];
    for &(tl, sl) in &part_new.near {
        near_of[tl as usize].push(sl);
    }
    for v in near_of.iter_mut() {
        v.sort_unstable();
    }

    let mut ptr = vec![0u32; n + 1];
    for (tl, sp) in part_new.leaves.iter().enumerate() {
        let row_nnz: usize = near_of[tl]
            .iter()
            .map(|&sl| part_new.leaves[sl as usize].len())
            .sum();
        assert!(row_nnz <= u32::MAX as usize);
        for i in sp.lo..sp.hi {
            ptr[i as usize + 1] = row_nnz as u32;
        }
    }
    for i in 0..n {
        let next = ptr[i]
            .checked_add(ptr[i + 1])
            .expect("near-field profile exceeds u32 nnz");
        ptr[i + 1] = next;
    }
    let nnz = ptr[n] as usize;

    // Reuse plan: per target leaf, the old blocks (in ascending source
    // ordinal) its rows can be copied from.
    let old_ord = cut_ordinals(part_old);
    let plan: Vec<Option<Vec<u32>>> = (0..nt)
        .map(|tl| {
            let tn = part_new.cut[tl] as usize;
            if !delta.clean[tn] {
                return None;
            }
            let ot = delta.node_map[tn];
            let otl = *old_ord.get(&ot)? as usize;
            if old_csb.tgt_leaves[otl].len() != part_new.leaves[tl].len() {
                return None;
            }
            let mut olst: Vec<(u32, u32)> = old_csb.by_target[otl]
                .iter()
                .map(|&bi| (old_csb.blocks[bi as usize].sleaf, bi))
                .collect();
            olst.sort_unstable();
            if olst.len() != near_of[tl].len() {
                return None;
            }
            let mut blocks = Vec::with_capacity(olst.len());
            for (&sl, &(osl, bi)) in near_of[tl].iter().zip(&olst) {
                let sn = part_new.cut[sl as usize] as usize;
                if !delta.clean[sn] {
                    return None;
                }
                let os = delta.node_map[sn];
                if *old_ord.get(&os)? != osl {
                    return None;
                }
                let b = &old_csb.blocks[bi as usize];
                if b.cols.len() != part_new.leaves[sl as usize].len()
                    || !matches!(b.kind, BlockKind::Dense { .. })
                {
                    return None;
                }
                blocks.push(bi);
            }
            Some(blocks)
        })
        .collect();

    let mut col = vec![0u32; nnz];
    let mut val = vec![0.0f32; nnz];
    {
        let cp = SendPtr(col.as_mut_ptr());
        let vp = SendPtr(val.as_mut_ptr());
        let (cpr, vpr) = (&cp, &vp);
        let ptr_ref = &ptr;
        let near_ref = &near_of;
        let leaves_ref = &part_new.leaves;
        let plan_ref = &plan;
        let pool = ThreadPool::new_or_default(threads);
        pool.for_each_chunked(nt, 1, |tl| {
            // SAFETY: a leaf's rows own the contiguous entry range
            // [ptr[lo], ptr[hi]); leaf row ranges are disjoint.
            let col_all: &mut [u32] = unsafe { std::slice::from_raw_parts_mut(cpr.0, nnz) };
            let val_all: &mut [f32] = unsafe { std::slice::from_raw_parts_mut(vpr.0, nnz) };
            let sp = leaves_ref[tl];
            for i in sp.lo..sp.hi {
                let mut e = ptr_ref[i as usize] as usize;
                let t = (i - sp.lo) as usize;
                match &plan_ref[tl] {
                    Some(blocks) => {
                        for (&sl, &bi) in near_ref[tl].iter().zip(blocks) {
                            let s = leaves_ref[sl as usize];
                            let b = &old_csb.blocks[bi as usize];
                            let BlockKind::Dense { off } = b.kind else {
                                unreachable!("reuse plan admits only dense blocks");
                            };
                            let w = b.cols.len();
                            val_all[e..e + w].copy_from_slice(
                                &old_csb.dense[off as usize + t * w..off as usize + (t + 1) * w],
                            );
                            for j in s.lo..s.hi {
                                col_all[e] = j;
                                e += 1;
                            }
                        }
                    }
                    None => {
                        for &sl in &near_ref[tl] {
                            let s = leaves_ref[sl as usize];
                            for j in s.lo..s.hi {
                                col_all[e] = j;
                                val_all[e] = gen.entry(i as usize, j as usize);
                                e += 1;
                            }
                        }
                    }
                }
                debug_assert_eq!(e, ptr_ref[i as usize + 1] as usize);
            }
        });
    }

    let reused_rows: u64 = plan
        .iter()
        .enumerate()
        .filter(|(_, p)| p.is_some())
        .map(|(tl, _)| part_new.leaves[tl].len() as u64)
        .sum();
    counters::add(Counter::UpdateNearRowsReused, reused_rows);

    Csr {
        rows: n,
        cols: n,
        ptr,
        col,
        val,
    }
}

impl FarField {
    /// Incremental counterpart of [`FarField::build`]: factor reuse for
    /// (clean cut leaf, clean source node) pairs the old partition also
    /// emitted, `aca_gauss` for everything else, then the shared assemble.
    /// Bit-identical to a fresh build over `part` at any thread count.
    #[allow(clippy::too_many_arguments)]
    pub fn update(
        old: &FarField,
        part_old: &Partition,
        part: &Partition,
        coords: &[f32],
        d: usize,
        inv_h2: f32,
        tol: f32,
        delta: &SideDelta,
        threads: usize,
    ) -> FarField {
        obs::span!("hmat.update");
        assert_eq!(coords.len(), part.n * d);
        assert_eq!(
            old.blocks.len(),
            part_old.far.len(),
            "old far field does not match the old partition"
        );
        let gen = GaussGen { coords, d, inv_h2 };
        let pool = ThreadPool::new_or_default(threads);

        let old_ord = cut_ordinals(part_old);
        let mut old_idx: HashMap<(u32, u32), u32> = HashMap::with_capacity(part_old.far.len());
        for (t, fb) in part_old.far.iter().enumerate() {
            old_idx.insert((fb.tleaf, fb.src_node), t as u32);
        }

        let reuse_of = |spec: &crate::hmat::admissible::FarBlockSpec| -> Option<u32> {
            let tn = part.cut[spec.tleaf as usize] as usize;
            let sn = spec.src_node as usize;
            if !delta.clean[tn] || !delta.clean[sn] {
                return None;
            }
            let otl = *old_ord.get(&delta.node_map[tn])?;
            let t = *old_idx.get(&(otl, delta.node_map[sn]))?;
            let ob = &old.blocks[t as usize];
            // Clean subtrees keep their populations, so the spans must
            // agree in size; anything else means the key aliased.
            if ob.rows.len() != spec.rows.len() || ob.cols.len() != spec.cols.len() {
                return None;
            }
            Some(t)
        };
        let plan: Vec<Option<u32>> = part.far.iter().map(reuse_of).collect();

        let factorize_span = obs::trace::SpanGuard::enter("hmat.update.factorize");
        let idx: Vec<usize> = (0..part.far.len()).collect();
        let factored: Vec<AcaFactor> = pool.map(&idx, |&t| match plan[t] {
            Some(ot) => lift_factor(old, ot as usize),
            None => {
                let fb = &part.far[t];
                aca_gauss(&gen, fb.rows, fb.cols, tol)
            }
        });
        drop(factorize_span);

        let reused = plan.iter().filter(|p| p.is_some()).count();
        counters::add(Counter::UpdateFarBlocksReused, reused as u64);
        counters::add(
            Counter::UpdateFarBlocksRefactored,
            (part.far.len() - reused) as u64,
        );

        Self::assemble(part, &factored, tol, &pool)
    }
}

/// Reconstruct block `t`'s [`AcaFactor`] from the old factor arena — the
/// inverse of the fill pass's copy, byte-preserving.
fn lift_factor(old: &FarField, t: usize) -> AcaFactor {
    let b = &old.blocks[t];
    let rn = b.rows.len();
    let cn = b.cols.len();
    match b.kind {
        FarKind::LowRank { u_off, vt_off, .. } => {
            let r = b.rank as usize;
            AcaFactor::LowRank {
                u: old.factors[u_off as usize..u_off as usize + rn * r].to_vec(),
                vt: old.factors[vt_off as usize..vt_off as usize + r * cn].to_vec(),
                rank: r,
            }
        }
        FarKind::Dense { off, .. } => {
            AcaFactor::Dense(old.factors[off as usize..off as usize + rn * cn].to_vec())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::csb::kernel::KernelKind;
    use crate::data::dataset::Dataset;
    use crate::data::synth::SynthSpec;
    use crate::hmat::admissible::partition;
    use crate::hmat::{FullKernelConfig, FullKernelEngine};
    use crate::tree::boxtree::BoxTree;
    use crate::tree::update::{update_tree, UpdateBatch};

    /// A spatially localized batch: delete the `n_del` interior points
    /// nearest a fixed interior anchor, insert `n_ins` midpoints between
    /// the anchor and its deleted neighbors.  Everything lands in one
    /// region, so subtrees (and far pairs) elsewhere stay clean —
    /// deterministic reuse, no seed sensitivity.
    fn localized_batch(ds: &Dataset, n_del: usize, n_ins: usize) -> UpdateBatch {
        let d = ds.d();
        let mut lo = vec![f32::INFINITY; d];
        let mut hi = vec![f32::NEG_INFINITY; d];
        for i in 0..ds.n() {
            for (a, &x) in ds.row(i).iter().enumerate() {
                lo[a] = lo[a].min(x);
                hi[a] = hi[a].max(x);
            }
        }
        let on_hull = |row: &[f32]| row.iter().enumerate().any(|(a, &x)| x == lo[a] || x == hi[a]);
        let interior: Vec<usize> = (0..ds.n()).filter(|&i| !on_hull(ds.row(i))).collect();
        let anchor = interior[0];
        let dist = |i: usize| -> f32 {
            ds.row(i)
                .iter()
                .zip(ds.row(anchor))
                .map(|(&x, &y)| (x - y) * (x - y))
                .sum()
        };
        let mut cand = interior;
        cand.sort_by(|&a, &b| dist(a).total_cmp(&dist(b)).then(a.cmp(&b)));
        let deletes: Vec<usize> = cand.iter().copied().take(n_del).collect();
        let mut inserts = Vec::new();
        for k in 0..n_ins {
            let p = deletes[k % deletes.len()];
            for (&x, &y) in ds.row(anchor).iter().zip(ds.row(p)) {
                inserts.push(0.5 * (x + y));
            }
        }
        UpdateBatch { deletes, inserts }
    }

    #[test]
    fn incremental_engine_matches_fresh_build() {
        let ds = SynthSpec::blobs(500, 3, 4, 47).generate();
        let tree = BoxTree::build(&ds, 8, 24);
        let coords = ds.permuted(&tree.perm).raw().to_vec();
        let cfg = FullKernelConfig::new(0.8).with_block_cap(64);
        let eng = FullKernelEngine::build(&tree, &coords, 3, &cfg, 2, 1, KernelKind::Scalar);

        let batch = localized_batch(&ds, 10, 10);
        let tu = update_tree(&tree, &ds, &batch, 24, 2);
        assert!(!tu.full_rebuild);
        let coords_new = tu.ds.permuted(&tu.tree.perm).raw().to_vec();
        let delta = SideDelta::from_update(&tree, &tu);

        let want =
            FullKernelEngine::build(&tu.tree, &coords_new, 3, &cfg, 1, 1, KernelKind::Scalar);
        for threads in [1usize, 2, 8] {
            let before_far = counters::get(Counter::UpdateFarBlocksReused);
            let before_near = counters::get(Counter::UpdateNearRowsReused);
            let got = eng.update(
                &tree,
                &tu.tree,
                &delta,
                &coords_new,
                3,
                &cfg,
                threads,
                1,
                KernelKind::Scalar,
            );
            assert_eq!(want.near.csb.blocks, got.near.csb.blocks, "threads={threads}");
            assert!(
                want.near
                    .csb
                    .dense
                    .iter()
                    .zip(&got.near.csb.dense)
                    .all(|(a, b)| a.to_bits() == b.to_bits()),
                "near dense arena differs, threads={threads}"
            );
            assert_eq!(want.near.csb.sp_ptr, got.near.csb.sp_ptr);
            assert!(
                want.far.bits_eq(&got.far),
                "far field differs, threads={threads}"
            );
            // Localized batch on clustered data must reuse both halves.
            assert!(
                counters::get(Counter::UpdateFarBlocksReused) > before_far,
                "no far factors reused"
            );
            assert!(
                counters::get(Counter::UpdateNearRowsReused) > before_near,
                "no near rows reused"
            );
        }
    }

    #[test]
    fn incremental_h2_engine_matches_fresh_build() {
        use crate::hmat::{FarFieldMode, Precision};
        for precision in [Precision::F32, Precision::Bf16] {
            let ds = SynthSpec::blobs(500, 3, 4, 47).generate();
            let tree = BoxTree::build(&ds, 8, 24);
            let coords = ds.permuted(&tree.perm).raw().to_vec();
            let cfg = FullKernelConfig::new(0.8)
                .with_block_cap(64)
                .with_far(FarFieldMode::H2)
                .with_precision(precision);
            let eng = FullKernelEngine::build(&tree, &coords, 3, &cfg, 2, 1, KernelKind::Scalar);

            let batch = localized_batch(&ds, 10, 10);
            let tu = update_tree(&tree, &ds, &batch, 24, 2);
            assert!(!tu.full_rebuild);
            let coords_new = tu.ds.permuted(&tu.tree.perm).raw().to_vec();
            let delta = SideDelta::from_update(&tree, &tu);

            let want =
                FullKernelEngine::build(&tu.tree, &coords_new, 3, &cfg, 1, 1, KernelKind::Scalar);
            for threads in [1usize, 2, 8] {
                let before = counters::get(Counter::UpdateH2LeavesReused);
                let got = eng.update(
                    &tree,
                    &tu.tree,
                    &delta,
                    &coords_new,
                    3,
                    &cfg,
                    threads,
                    1,
                    KernelKind::Scalar,
                );
                assert!(
                    want.far.bits_eq(&got.far),
                    "h2 far field differs, threads={threads} precision={precision:?}"
                );
                assert!(
                    counters::get(Counter::UpdateH2LeavesReused) > before,
                    "no h2 leaf bases reused (precision={precision:?})"
                );
            }
        }
    }

    #[test]
    fn near_profile_update_with_identity_delta_is_pure_copy() {
        let ds = SynthSpec::blobs(400, 3, 4, 59).generate();
        let tree = BoxTree::build(&ds, 8, 24);
        let coords = ds.permuted(&tree.perm).raw().to_vec();
        let part = partition(&tree, 64, 1.0);
        let fresh = crate::hmat::near_profile(&part, &coords, 3, 0.8, 2);
        let csb = HierCsb::build_with_par(&fresh, &tree, &tree, 64, 0.5, 1);
        let delta = SideDelta::identity(&tree);
        let upd = near_profile_update(&part, &part, &csb, &coords, 3, 0.8, &delta, 2);
        assert_eq!(fresh.ptr, upd.ptr);
        assert_eq!(fresh.col, upd.col);
        assert!(fresh.val.iter().zip(&upd.val).all(|(a, b)| a.to_bits() == b.to_bits()));
    }
}
