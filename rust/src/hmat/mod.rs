//! Far-field low-rank subsystem: the full Gaussian kernel operator.
//!
//! The paper's pipeline truncates the interaction matrix to kNN-induced
//! blocks — the *near field*.  This module compresses everything the
//! truncation drops: an η-admissibility partition
//! ([`admissible`]) splits the `n x n` index space into near rectangles
//! (stored as fully dense `HierCsb` blocks through the existing build)
//! and admissible far rectangles, each factorized to low rank by
//! partial-pivot ACA ([`aca`]) with a dense fallback, stored in flat
//! aligned arenas ([`store`]), and applied through the dispatched
//! `csb::kernel` GEMMs under target-leaf ownership ([`apply`]).
//!
//! [`FullKernelEngine`] fuses the two halves behind one
//! `spmv`/`spmm`/`gauss_apply_multi` surface: `y = K·x` with
//! `K_ij = exp(−‖x_i − x_j‖²·inv_h2)` over **all** `n²` pairs, at
//! `O(near_area + Σ r·(rn+cn))` storage and work.  This unlocks the
//! workloads the truncated profile cannot serve — Gaussian kernel ridge
//! regression ([`crate::apps::krr`]), untruncated mean shift — while
//! reusing every established mechanism: the `BoxTree` cut, the `HierCsb`
//! arenas and panels, the `Engine` schedule and per-worker scratch, and
//! the deterministic count→scan→parallel-fill build discipline.
//!
//! Accuracy contract: the compressed operator matches an O(n²) f64 dense
//! oracle to ~`tol` relative error (near blocks are exact at f32
//! resolution; each far block carries ≤ tol relative Frobenius error —
//! `rust/tests/full_kernel.rs`, `rust/tests/prop_invariants.rs`).

pub mod aca;
pub mod admissible;
pub mod apply;
pub mod h2;
pub mod repr;
pub mod store;
pub mod update;

use crate::csb::hier::{HierCsb, LEAF_POINTS};
use crate::csb::update::SideDelta;
use crate::csb::kernel::KernelKind;
use crate::csb::panel::AlignedF32;
use crate::hmat::admissible::Partition;
use crate::hmat::h2::H2Field;
use crate::hmat::repr::{FarFieldRepr, FarFieldStore};
use crate::hmat::store::FarField;
use crate::interact::engine::Engine;
use crate::par::pool::{SendPtr, ThreadPool};
use crate::sparse::csr::Csr;
use crate::tree::boxtree::BoxTree;
use std::sync::Mutex;

/// Far-field handling of a full-kernel engine.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum FarFieldMode {
    /// Near field only — the truncated baseline (`--far off`).
    Off,
    /// ACA-compressed far field, one independent factor pair per block.
    #[default]
    Aca,
    /// Nested cluster bases + transfer matrices + skeleton couplings
    /// ([`h2`]) — same accuracy contract, O(n)-class storage.
    H2,
}

impl FarFieldMode {
    pub fn parse(s: &str) -> Result<FarFieldMode, String> {
        match s.to_ascii_lowercase().as_str() {
            "off" => Ok(FarFieldMode::Off),
            "aca" => Ok(FarFieldMode::Aca),
            "h2" => Ok(FarFieldMode::H2),
            other => Err(format!("unknown far-field mode '{other}' (off|aca|h2)")),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            FarFieldMode::Off => "off",
            FarFieldMode::Aca => "aca",
            FarFieldMode::H2 => "h2",
        }
    }
}

/// Far-field factor storage precision.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Precision {
    /// All factors stored as f32 (with packed AVX2 panels).
    #[default]
    F32,
    /// Per-factor bf16-in-u16 where the rounded image stays within the
    /// tolerance budget; everything else stays f32 ([`h2`] module docs).
    /// Only the H² representation consumes this today.
    Bf16,
}

impl Precision {
    pub fn parse(s: &str) -> Result<Precision, String> {
        match s.to_ascii_lowercase().as_str() {
            "f32" => Ok(Precision::F32),
            "bf16" => Ok(Precision::Bf16),
            other => Err(format!("unknown precision '{other}' (f32|bf16)")),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Precision::F32 => "f32",
            Precision::Bf16 => "bf16",
        }
    }
}

/// Construction parameters of a [`FullKernelEngine`].
#[derive(Clone, Debug)]
pub struct FullKernelConfig {
    /// Gaussian bandwidth as `1/h²`.
    pub inv_h2: f32,
    /// Admissibility parameter (see [`admissible`]); larger η admits
    /// closer pairs into the far field.
    pub eta: f32,
    /// ACA relative Frobenius tolerance per far block.
    pub tol: f32,
    /// Leaf blocking capacity (0 = [`LEAF_POINTS`], the `HierCsb`
    /// default).
    pub block_cap: usize,
    /// Far-field handling.
    pub far: FarFieldMode,
    /// Far-field factor storage precision (H² only today).
    pub precision: Precision,
}

impl FullKernelConfig {
    pub fn new(inv_h2: f32) -> FullKernelConfig {
        FullKernelConfig {
            inv_h2,
            eta: 1.0,
            tol: 1e-3,
            block_cap: 0,
            far: FarFieldMode::Aca,
            precision: Precision::F32,
        }
    }

    pub fn with_eta(mut self, eta: f32) -> Self {
        self.eta = eta;
        self
    }

    pub fn with_tol(mut self, tol: f32) -> Self {
        self.tol = tol;
        self
    }

    pub fn with_block_cap(mut self, cap: usize) -> Self {
        self.block_cap = cap;
        self
    }

    pub fn with_far(mut self, far: FarFieldMode) -> Self {
        self.far = far;
        self
    }

    pub fn with_precision(mut self, precision: Precision) -> Self {
        self.precision = precision;
        self
    }
}

/// The fused full-kernel operator: near field through the established
/// [`Engine`] (Gaussian weights baked into dense `HierCsb` blocks at
/// build time, so every apply is a stored-value SpMM over the
/// precompiled schedule), far field accumulated on top through the
/// [`FarFieldRepr`] seam (per-block ACA or nested-basis H², per
/// `cfg.far`).  Both halves run the same kernel dispatch and thread
/// pool; with the scalar kernel the whole apply is bit-exact across
/// thread counts.
pub struct FullKernelEngine {
    pub near: Engine,
    pub far: FarFieldStore,
    /// Coordinate dimension of the Gaussian.
    pub dim: usize,
    pub inv_h2: f32,
    far_scratch: Vec<Mutex<AlignedF32>>,
}

impl FullKernelEngine {
    /// Build over `tree` (the dual-tree ordering hierarchy) and
    /// **tree-ordered** coordinates `coords` (row-major `n x dim` — the
    /// space the Gaussian lives in, typically the original features, not
    /// the ordering embedding).  `build_threads`/`threads` follow the
    /// usual convention (0 = machine default); the build is bit-identical
    /// across `build_threads`.
    pub fn build(
        tree: &BoxTree,
        coords: &[f32],
        dim: usize,
        cfg: &FullKernelConfig,
        build_threads: usize,
        threads: usize,
        kernel: KernelKind,
    ) -> FullKernelEngine {
        crate::obs::span!("hmat.engine.build");
        let n = tree.n();
        assert_eq!(coords.len(), n * dim, "coords must be tree-ordered n x dim");
        assert!(cfg.inv_h2 > 0.0 && cfg.inv_h2.is_finite(), "inv_h2 must be positive");
        let block_cap = if cfg.block_cap == 0 { LEAF_POINTS } else { cfg.block_cap };
        let part = admissible::partition(tree, block_cap, cfg.eta);
        let near_csr = near_profile(&part, coords, dim, cfg.inv_h2, build_threads);
        // Threshold 0.5 is immaterial: every near block is fully populated
        // (density exactly 1.0), so all of them store dense + panel-packed.
        let csb = HierCsb::build_with_par(&near_csr, tree, tree, block_cap, 0.5, build_threads);
        debug_assert_eq!(csb.tgt_leaves, part.leaves, "near cut must match the partition cut");
        let far = match cfg.far {
            FarFieldMode::Off => FarFieldStore::Aca(FarField::empty(&part, cfg.tol)),
            FarFieldMode::Aca => {
                let f = FarField::build(&part, coords, dim, cfg.inv_h2, cfg.tol, build_threads);
                debug_assert_eq!(
                    csb.coverage().0 + f.coverage(),
                    n as u64 * n as u64,
                    "near + far must tile the index space"
                );
                FarFieldStore::Aca(f)
            }
            FarFieldMode::H2 => {
                let f = H2Field::build(
                    &part,
                    coords,
                    dim,
                    cfg.inv_h2,
                    cfg.tol,
                    cfg.precision,
                    build_threads,
                );
                debug_assert_eq!(
                    csb.coverage().0 + f.coverage(),
                    n as u64 * n as u64,
                    "near + far must tile the index space"
                );
                FarFieldStore::H2(f)
            }
        };
        let near = Engine::with_kernel(csb, threads, kernel);
        let far_scratch = apply::worker_scratch(near.pool.threads);
        FullKernelEngine {
            near,
            far,
            dim,
            inv_h2: cfg.inv_h2,
            far_scratch,
        }
    }

    /// Incremental rebuild against a tree update: the near profile reuses
    /// the Gaussian rows of clean target leaves straight out of this
    /// engine's dense arenas ([`update::near_profile_update`] — the `exp`
    /// regeneration is the dominant near-side cost), the far field lifts
    /// the ACA factors of untouched (cut leaf, source node) pairs
    /// ([`FarField::update`]), and everything else regenerates.  `self`
    /// is untouched — existing handles keep applying against their
    /// snapshot — and the result is bit-identical to
    /// [`FullKernelEngine::build`] over `new_tree` at any
    /// `build_threads`.  `cfg` must match the one this engine was built
    /// with; `coords` are the **new** tree-ordered coordinates.
    #[allow(clippy::too_many_arguments)]
    pub fn update(
        &self,
        old_tree: &BoxTree,
        new_tree: &BoxTree,
        delta: &SideDelta,
        coords: &[f32],
        dim: usize,
        cfg: &FullKernelConfig,
        build_threads: usize,
        threads: usize,
        kernel: KernelKind,
    ) -> FullKernelEngine {
        crate::obs::span!("hmat.engine.update");
        let n = new_tree.n();
        assert_eq!(coords.len(), n * dim, "coords must be tree-ordered n x dim");
        assert_eq!(dim, self.dim, "dimension must match the built engine");
        let block_cap = if cfg.block_cap == 0 { LEAF_POINTS } else { cfg.block_cap };
        let part_old = admissible::partition(old_tree, block_cap, cfg.eta);
        let part = admissible::partition(new_tree, block_cap, cfg.eta);
        let near_csr = update::near_profile_update(
            &part,
            &part_old,
            &self.near.csb,
            coords,
            dim,
            cfg.inv_h2,
            delta,
            build_threads,
        );
        let csb = HierCsb::build_with_par(&near_csr, new_tree, new_tree, block_cap, 0.5, build_threads);
        let far = match cfg.far {
            FarFieldMode::Off => FarFieldStore::Aca(FarField::empty(&part, cfg.tol)),
            FarFieldMode::Aca => {
                // Representation mismatch (engine built with a different
                // `cfg.far`) falls back to a from-scratch build — the
                // result is bit-identical either way.
                let f = match self.far.as_aca() {
                    Some(old) if old.blocks.len() == part_old.far.len() => FarField::update(
                        old,
                        &part_old,
                        &part,
                        coords,
                        dim,
                        cfg.inv_h2,
                        cfg.tol,
                        delta,
                        build_threads,
                    ),
                    _ => FarField::build(&part, coords, dim, cfg.inv_h2, cfg.tol, build_threads),
                };
                FarFieldStore::Aca(f)
            }
            FarFieldMode::H2 => {
                let f = match self.far.as_h2() {
                    Some(old) => H2Field::update(
                        old,
                        &part_old,
                        &part,
                        coords,
                        dim,
                        cfg.inv_h2,
                        cfg.tol,
                        cfg.precision,
                        delta,
                        build_threads,
                    ),
                    None => H2Field::build(
                        &part,
                        coords,
                        dim,
                        cfg.inv_h2,
                        cfg.tol,
                        cfg.precision,
                        build_threads,
                    ),
                };
                FarFieldStore::H2(f)
            }
        };
        let near = Engine::with_kernel(csb, threads, kernel);
        let far_scratch = apply::worker_scratch(near.pool.threads);
        FullKernelEngine {
            near,
            far,
            dim,
            inv_h2: cfg.inv_h2,
            far_scratch,
        }
    }

    pub fn n(&self) -> usize {
        self.near.csb.rows
    }

    /// `Y = K·X` with `k` RHS columns (`x`: `n x k`, `y`: `n x k`,
    /// row-major; `y` overwritten).
    pub fn spmm(&self, x: &[f32], y: &mut [f32], k: usize) {
        self.near.spmm(x, y, k);
        self.far
            .apply_acc(x, k, y, &self.near.pool, self.near.dispatch(), &self.far_scratch);
    }

    /// `y = K·x` (`k = 1` [`FullKernelEngine::spmm`]).
    pub fn spmv(&self, x: &[f32], y: &mut [f32]) {
        self.spmm(x, y, 1);
    }

    /// Far field only, accumulating: `y += K_far·x` (`x`/`y` row-major
    /// `n x k`).  Public seam for callers that compute the near field
    /// themselves in pieces — the serve tier's sharded workers produce
    /// near-row partials, the coordinator merges them and applies the far
    /// field once on the merged buffer (uniform across Off/Aca/H2, and
    /// bit-identical to [`FullKernelEngine::spmm`] on the same inputs).
    pub fn far_apply_acc(&self, x: &[f32], k: usize, y: &mut [f32]) {
        self.far
            .apply_acc(x, k, y, &self.near.pool, self.near.dispatch(), &self.far_scratch);
    }

    /// Multi-query Gaussian apply over the **full** kernel — the
    /// far-field-complete counterpart of [`Engine::gauss_apply_multi`].
    /// The Gaussian weights are baked into storage at build time
    /// (near: dense block values; far: compressed factors), so this is
    /// exactly [`FullKernelEngine::spmm`].
    pub fn gauss_apply_multi(&self, x: &[f32], k: usize, y_out: &mut [f32]) {
        self.spmm(x, y_out, k);
    }

    /// Near + far storage bytes (factor arenas; panel mirrors excluded,
    /// matching `HierCsb` accounting).
    pub fn stored_bytes(&self) -> u64 {
        let near = (self.near.csb.dense.len() + self.near.csb.sp_val.len()) as u64 * 4;
        near + self.far.far_bytes()
    }

    /// Stats line for logs/benches.
    pub fn describe(&self) -> String {
        format!(
            "near[{}] far[{}] eta={} tol={:.0e}",
            self.near.csb.describe(),
            self.far.describe(),
            self.far.eta(),
            self.far.tol()
        )
    }
}

/// Materialize the near-field profile as a CSR whose values are the
/// **exact Gaussian weights**: every (row, column) pair inside a near
/// rectangle gets `exp(−‖x_i − x_j‖²·inv_h2)`.  Each near block comes out
/// fully populated (density 1.0 → dense `HierCsb` storage + packed
/// panels), so the near apply is a plain stored-value SpMM — no per-apply
/// transcendental recompute.  Fill is parallel over target leaves
/// (disjoint row ranges) and each value is a pure function of its entry,
/// so the CSR is bit-identical across thread counts.
pub(crate) fn near_profile(
    part: &Partition,
    coords: &[f32],
    d: usize,
    inv_h2: f32,
    threads: usize,
) -> Csr {
    let n = part.n;
    let gen = aca::GaussGen { coords, d, inv_h2 };
    // Per target leaf: near source spans sorted by span start, so row
    // columns come out ascending (spans are disjoint).
    let nt = part.leaves.len();
    let mut spans: Vec<Vec<crate::csb::hier::Span>> = vec![Vec::new(); nt];
    for &(tl, sl) in &part.near {
        spans[tl as usize].push(part.leaves[sl as usize]);
    }
    for v in spans.iter_mut() {
        v.sort_unstable_by_key(|s| s.lo);
    }

    let mut ptr = vec![0u32; n + 1];
    for (tl, sp) in part.leaves.iter().enumerate() {
        let row_nnz: usize = spans[tl].iter().map(|s| s.len()).sum();
        assert!(row_nnz <= u32::MAX as usize);
        for i in sp.lo..sp.hi {
            ptr[i as usize + 1] = row_nnz as u32;
        }
    }
    for i in 0..n {
        let next = ptr[i]
            .checked_add(ptr[i + 1])
            .expect("near-field profile exceeds u32 nnz");
        ptr[i + 1] = next;
    }
    let nnz = ptr[n] as usize;
    let mut col = vec![0u32; nnz];
    let mut val = vec![0.0f32; nnz];
    {
        let cp = SendPtr(col.as_mut_ptr());
        let vp = SendPtr(val.as_mut_ptr());
        let (cpr, vpr) = (&cp, &vp);
        let ptr_ref = &ptr;
        let spans_ref = &spans;
        let leaves_ref = &part.leaves;
        let pool = ThreadPool::new_or_default(threads);
        pool.for_each_chunked(nt, 1, |tl| {
            // SAFETY: a leaf's rows own the contiguous entry range
            // [ptr[lo], ptr[hi]); leaf row ranges are disjoint.
            let col_all: &mut [u32] = unsafe { std::slice::from_raw_parts_mut(cpr.0, nnz) };
            let val_all: &mut [f32] = unsafe { std::slice::from_raw_parts_mut(vpr.0, nnz) };
            let sp = leaves_ref[tl];
            for i in sp.lo..sp.hi {
                let mut e = ptr_ref[i as usize] as usize;
                for s in &spans_ref[tl] {
                    for j in s.lo..s.hi {
                        col_all[e] = j;
                        val_all[e] = gen.entry(i as usize, j as usize);
                        e += 1;
                    }
                }
                debug_assert_eq!(e, ptr_ref[i as usize + 1] as usize);
            }
        });
    }
    Csr {
        rows: n,
        cols: n,
        ptr,
        col,
        val,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;
    use crate::util::rng::Rng;

    fn build_engine(
        n: usize,
        cfg_mut: impl FnOnce(&mut FullKernelConfig),
    ) -> (Vec<f32>, FullKernelEngine) {
        let ds = SynthSpec::blobs(n, 3, 4, 41).generate();
        let tree = BoxTree::build(&ds, 8, 24);
        let coords = ds.permuted(&tree.perm).raw().to_vec();
        let mut cfg = FullKernelConfig::new(0.8).with_block_cap(64);
        cfg_mut(&mut cfg);
        let eng = FullKernelEngine::build(&tree, &coords, 3, &cfg, 2, 2, KernelKind::Scalar);
        (coords, eng)
    }

    /// Dense f64 oracle `y = K x` over all pairs.
    fn oracle_spmv(coords: &[f32], d: usize, inv_h2: f32, x: &[f32]) -> Vec<f64> {
        let n = x.len();
        let gen = aca::GaussGen { coords, d, inv_h2 };
        (0..n)
            .map(|i| (0..n).map(|j| gen.entry_f64(i, j) * x[j] as f64).sum())
            .collect()
    }

    #[test]
    fn full_spmv_matches_dense_oracle() {
        let (coords, eng) = build_engine(600, |_| {});
        let mut rng = Rng::new(5);
        let x: Vec<f32> = (0..600).map(|_| rng.f32() - 0.5).collect();
        let want = oracle_spmv(&coords, 3, 0.8, &x);
        let mut got = vec![0.0f32; 600];
        eng.spmv(&x, &mut got);
        let norm: f64 = want.iter().map(|w| w * w).sum::<f64>().sqrt();
        let err: f64 = got
            .iter()
            .zip(&want)
            .map(|(&g, &w)| (g as f64 - w) * (g as f64 - w))
            .sum::<f64>()
            .sqrt();
        assert!(
            err <= 10.0 * 1e-3 * norm,
            "full-kernel spmv err {err} vs 10·tol·norm {} ({})",
            1e-2 * norm,
            eng.describe()
        );
    }

    #[test]
    fn far_off_reproduces_near_field_only() {
        let (coords, eng_full) = build_engine(400, |_| {});
        let (_, eng_off) = build_engine(400, |c| c.far = FarFieldMode::Off);
        assert!(eng_off.far.is_empty());
        let mut rng = Rng::new(9);
        let x: Vec<f32> = (0..400).map(|_| rng.f32()).collect();
        let mut y_off = vec![0.0f32; 400];
        eng_off.spmv(&x, &mut y_off);
        let mut y_near = vec![0.0f32; 400];
        eng_full.near.spmv(&x, &mut y_near);
        assert_eq!(y_off, y_near, "far=off must equal the bare near field");
        let _ = coords;
    }

    #[test]
    fn near_blocks_are_fully_dense() {
        let (_, eng) = build_engine(500, |_| {});
        assert!(
            (eng.near.csb.dense_fraction() - 1.0).abs() < 1e-12,
            "near blocks must all store dense: {}",
            eng.near.csb.describe()
        );
        for b in &eng.near.csb.blocks {
            assert_eq!(
                b.nnz as u64,
                b.rows.len() as u64 * b.cols.len() as u64,
                "near block not fully populated"
            );
        }
    }

    #[test]
    fn spmm_columns_match_spmv_bitexact() {
        let (_, eng) = build_engine(500, |_| {});
        let n = 500;
        let mut rng = Rng::new(13);
        let k = 4;
        let x: Vec<f32> = (0..n * k).map(|_| rng.f32() - 0.5).collect();
        let mut y = vec![0.0f32; n * k];
        eng.gauss_apply_multi(&x, k, &mut y);
        for j in 0..k {
            let xj: Vec<f32> = (0..n).map(|i| x[i * k + j]).collect();
            let mut yj = vec![0.0f32; n];
            eng.spmv(&xj, &mut yj);
            for i in 0..n {
                assert_eq!(y[i * k + j].to_bits(), yj[i].to_bits(), "col {j} row {i}");
            }
        }
    }

    #[test]
    fn build_bitidentical_across_build_threads() {
        let ds = SynthSpec::blobs(500, 3, 4, 51).generate();
        let tree = BoxTree::build(&ds, 8, 24);
        let coords = ds.permuted(&tree.perm).raw().to_vec();
        let cfg = FullKernelConfig::new(0.8).with_block_cap(64);
        for far in [FarFieldMode::Aca, FarFieldMode::H2] {
            let cfg = cfg.clone().with_far(far);
            let r1 = FullKernelEngine::build(&tree, &coords, 3, &cfg, 1, 1, KernelKind::Scalar);
            for bt in [2usize, 8] {
                let r = FullKernelEngine::build(&tree, &coords, 3, &cfg, bt, 1, KernelKind::Scalar);
                assert_eq!(r.near.csb.blocks, r1.near.csb.blocks, "build_threads={bt}");
                assert!(r
                    .near
                    .csb
                    .dense
                    .iter()
                    .zip(&r1.near.csb.dense)
                    .all(|(a, b)| a.to_bits() == b.to_bits()));
                assert!(
                    r.far.bits_eq(&r1.far),
                    "far field differs at build_threads={bt} far={}",
                    far.label()
                );
            }
        }
    }

    #[test]
    fn h2_engine_matches_dense_oracle() {
        let (coords, eng) = build_engine(600, |c| c.far = FarFieldMode::H2);
        let mut rng = Rng::new(5);
        let x: Vec<f32> = (0..600).map(|_| rng.f32() - 0.5).collect();
        let want = oracle_spmv(&coords, 3, 0.8, &x);
        let mut got = vec![0.0f32; 600];
        eng.spmv(&x, &mut got);
        let norm: f64 = want.iter().map(|w| w * w).sum::<f64>().sqrt();
        let err: f64 = got
            .iter()
            .zip(&want)
            .map(|(&g, &w)| (g as f64 - w) * (g as f64 - w))
            .sum::<f64>()
            .sqrt();
        assert!(
            err <= 10.0 * 1e-3 * norm,
            "h2 full-kernel spmv err {err} vs 10·tol·norm {} ({})",
            1e-2 * norm,
            eng.describe()
        );
    }
}
