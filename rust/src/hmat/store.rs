//! Far-field factor storage: flat arenas + packed panels.
//!
//! All ACA factors live in **one** f32 arena (`factors`) addressed by
//! exclusive-scan offsets, and every dense operand additionally gets a
//! tile-major, 32-byte-aligned panel copy (reusing [`crate::csb::panel`])
//! so the far GEMMs ride the same AVX2 path as the near blocks.  The
//! build follows the `HierCsb::build_with_par` discipline:
//!
//! 1. **factorize** — `aca_gauss` per far block through the pool's
//!    order-preserving `map` (each factorization is sequential and a pure
//!    function of its block, so the result is independent of the thread
//!    count);
//! 2. **scan** — serial exclusive scan of factor / panel footprints into
//!    per-block offsets;
//! 3. **fill** — parallel copy + panel pack into the two arenas, every
//!    region owned by exactly one block.
//!
//! The arenas are therefore **bit-identical across thread counts** — the
//! same contract as the near-field build, asserted by
//! `benches/farfield.rs` before anything is recorded.

use crate::csb::hier::Span;
use crate::csb::panel::{pack_panel, panel_len, AlignedF32};
use crate::hmat::aca::{aca_gauss, AcaFactor, GaussGen};
use crate::hmat::admissible::Partition;
use crate::obs::{self, counters, Counter};
use crate::par::pool::{SendPtr, ThreadPool};

/// Payload locator of one far block inside the [`FarField`] arenas.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FarKind {
    /// `U` row-major `rows x rank` at `factors[u_off..]`, `Vᵀ` row-major
    /// `rank x cols` at `factors[vt_off..]`; `u_poff`/`vt_poff` locate the
    /// packed panels.
    LowRank {
        u_off: u32,
        vt_off: u32,
        u_poff: u32,
        vt_poff: u32,
    },
    /// Dense fallback values, row-major at `factors[off..]`, panel at
    /// `panels[poff..]`.
    Dense { off: u32, poff: u32 },
}

/// One compressed far block (rows = exactly one target cut leaf).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FarBlock {
    /// Owning target-leaf ordinal (same cut as the near `HierCsb`).
    pub tleaf: u32,
    pub rows: Span,
    pub cols: Span,
    /// Factorization rank (0 for numerically zero blocks; unused for the
    /// dense fallback).
    pub rank: u32,
    pub kind: FarKind,
}

impl FarBlock {
    pub fn area(&self) -> u64 {
        self.rows.len() as u64 * self.cols.len() as u64
    }

    pub fn is_dense(&self) -> bool {
        matches!(self.kind, FarKind::Dense { .. })
    }
}

/// The compressed far field of a full-kernel operator.
#[derive(Clone, Debug)]
pub struct FarField {
    pub rows: usize,
    pub cols: usize,
    /// Target-leaf row blocking (identical to the near `HierCsb`'s
    /// `tgt_leaves` — both derive from the same size cut).
    pub tgt_leaves: Vec<Span>,
    /// Far blocks in partition (traversal) order.
    pub blocks: Vec<FarBlock>,
    /// Per target leaf: indices into `blocks`.
    pub by_target: Vec<Vec<u32>>,
    /// Non-empty target-leaf ordinals (the apply task list), heaviest
    /// first by compressed flops so the dynamic claim schedules long
    /// poles early.
    pub tasks: Vec<u32>,
    /// Row-major factor arena (U / Vᵀ / dense regions, scan-ordered).
    pub factors: Vec<f32>,
    /// Tile-major 32-byte-aligned panel copies of every factor region.
    pub panels: AlignedF32,
    /// Admissibility parameter and ACA tolerance the field was built with.
    pub eta: f32,
    pub tol: f32,
}

impl FarField {
    /// Compress `part`'s far blocks over tree-ordered `coords`
    /// (row-major `n x d`) with Gaussian bandwidth `inv_h2 = 1/h²`.
    /// `threads = 0` means the machine default; the result is
    /// bit-identical across thread counts (module docs).
    pub fn build(
        part: &Partition,
        coords: &[f32],
        d: usize,
        inv_h2: f32,
        tol: f32,
        threads: usize,
    ) -> FarField {
        obs::span!("hmat.build");
        assert_eq!(coords.len(), part.n * d);
        let gen = GaussGen { coords, d, inv_h2 };
        let pool = ThreadPool::new_or_default(threads);

        // Pass 1 — factorize (order-preserving parallel map).
        let factorize_span = obs::trace::SpanGuard::enter("hmat.factorize");
        let factored: Vec<AcaFactor> =
            pool.map(&part.far, |fb| aca_gauss(&gen, fb.rows, fb.cols, tol));
        drop(factorize_span);

        Self::assemble(part, &factored, tol, &pool)
    }

    /// Passes 2–3 of the build — scan, fill, counters, task order — shared
    /// with the incremental update (`hmat::update`), which swaps pass 1 for
    /// a reuse-or-refactor mix.  A pure function of `(part, factored)`.
    pub(crate) fn assemble(
        part: &Partition,
        factored: &[AcaFactor],
        tol: f32,
        pool: &ThreadPool,
    ) -> FarField {
        // Pass 2 — exclusive scan of arena footprints.
        let scan_span = obs::trace::SpanGuard::enter("hmat.scan");
        let mut blocks: Vec<FarBlock> = Vec::with_capacity(part.far.len());
        let mut flen = 0usize;
        let mut plen = 0usize;
        for (fb, f) in part.far.iter().zip(factored) {
            let rn = fb.rows.len();
            let cn = fb.cols.len();
            let (rank, kind) = match f {
                AcaFactor::LowRank { rank, .. } => {
                    let r = *rank;
                    let u_off = flen as u32;
                    flen += rn * r;
                    let vt_off = flen as u32;
                    flen += r * cn;
                    let u_poff = plen as u32;
                    plen += panel_len(rn, r);
                    let vt_poff = plen as u32;
                    plen += panel_len(r, cn);
                    (
                        r as u32,
                        FarKind::LowRank {
                            u_off,
                            vt_off,
                            u_poff,
                            vt_poff,
                        },
                    )
                }
                AcaFactor::Dense(_) => {
                    let off = flen as u32;
                    flen += rn * cn;
                    let poff = plen as u32;
                    plen += panel_len(rn, cn);
                    (0, FarKind::Dense { off, poff })
                }
            };
            blocks.push(FarBlock {
                tleaf: fb.tleaf,
                rows: fb.rows,
                cols: fb.cols,
                rank,
                kind,
            });
        }
        assert!(flen <= u32::MAX as usize, "far factor arena exceeds u32 offsets");
        assert!(plen <= u32::MAX as usize, "far panel arena exceeds u32 offsets");
        drop(scan_span);

        // Pass 3 — parallel fill: copy factors + pack panels into the
        // per-block regions (disjoint by the scan).
        let fill_span = obs::trace::SpanGuard::enter("hmat.fill");
        let mut factors = vec![0.0f32; flen];
        let mut panels = AlignedF32::zeroed(plen);
        {
            let fp = SendPtr(factors.as_mut_ptr());
            let pp = SendPtr(panels.as_mut_slice().as_mut_ptr());
            let (fpr, ppr) = (&fp, &pp);
            let blocks_ref = &blocks;
            let factored_ref = &factored;
            pool.for_each_chunked(blocks_ref.len(), 4, |t| {
                let b = &blocks_ref[t];
                let rn = b.rows.len();
                let cn = b.cols.len();
                // SAFETY: each block's factor/panel regions are disjoint
                // by the exclusive scan; this task touches only block t's.
                let copy_and_pack = |src: &[f32], nr: usize, nc: usize, off: u32, poff: u32| {
                    debug_assert_eq!(src.len(), nr * nc);
                    let dst: &mut [f32] = unsafe {
                        std::slice::from_raw_parts_mut(fpr.0.add(off as usize), nr * nc)
                    };
                    dst.copy_from_slice(src);
                    let pl = panel_len(nr, nc);
                    let pdst: &mut [f32] = unsafe {
                        std::slice::from_raw_parts_mut(ppr.0.add(poff as usize), pl)
                    };
                    pack_panel(src, nr, nc, pdst);
                };
                match (&factored_ref[t], b.kind) {
                    (
                        AcaFactor::LowRank { u, vt, rank },
                        FarKind::LowRank {
                            u_off,
                            vt_off,
                            u_poff,
                            vt_poff,
                        },
                    ) => {
                        copy_and_pack(u, rn, *rank, u_off, u_poff);
                        copy_and_pack(vt, *rank, cn, vt_off, vt_poff);
                    }
                    (AcaFactor::Dense(v), FarKind::Dense { off, poff }) => {
                        copy_and_pack(v, rn, cn, off, poff);
                    }
                    _ => unreachable!("scan and factorization disagree on block kind"),
                }
            });
        }
        drop(fill_span);

        // Fold compression outcomes into the global counter registry.
        counters::add(Counter::AcaBlocks, blocks.len() as u64);
        counters::add(
            Counter::AcaRankSum,
            blocks.iter().filter(|b| !b.is_dense()).map(|b| b.rank as u64).sum(),
        );
        counters::raise(
            Counter::AcaRankMax,
            blocks.iter().map(|b| b.rank as u64).max().unwrap_or(0),
        );
        counters::add(Counter::AcaFactorBytes, flen as u64 * 4);
        counters::add(
            Counter::AcaDenseFallbacks,
            blocks.iter().filter(|b| b.is_dense()).count() as u64,
        );

        let nt = part.leaves.len();
        let mut by_target: Vec<Vec<u32>> = vec![Vec::new(); nt];
        for (t, b) in blocks.iter().enumerate() {
            by_target[b.tleaf as usize].push(t as u32);
        }
        // Heaviest-first task order by compressed flops (ties by ordinal),
        // mirroring `ApplySchedule`.
        let flops: Vec<u64> = by_target
            .iter()
            .map(|list| {
                list.iter()
                    .map(|&t| {
                        let b = &blocks[t as usize];
                        match b.kind {
                            FarKind::LowRank { .. } => {
                                b.rank as u64 * (b.rows.len() + b.cols.len()) as u64
                            }
                            FarKind::Dense { .. } => b.area(),
                        }
                    })
                    .sum()
            })
            .collect();
        let mut tasks: Vec<u32> = (0..nt as u32)
            .filter(|&tl| !by_target[tl as usize].is_empty())
            .collect();
        tasks.sort_by_key(|&tl| (std::cmp::Reverse(flops[tl as usize]), tl));

        FarField {
            rows: part.n,
            cols: part.n,
            tgt_leaves: part.leaves.clone(),
            blocks,
            by_target,
            tasks,
            factors,
            panels,
            eta: part.eta,
            tol,
        }
    }

    /// An empty far field over the same leaf blocking (`--far off`: the
    /// operator degrades to the near field alone).
    pub fn empty(part: &Partition, tol: f32) -> FarField {
        FarField {
            rows: part.n,
            cols: part.n,
            tgt_leaves: part.leaves.clone(),
            blocks: Vec::new(),
            by_target: vec![Vec::new(); part.leaves.len()],
            tasks: Vec::new(),
            factors: Vec::new(),
            panels: AlignedF32::zeroed(0),
            eta: part.eta,
            tol,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.blocks.is_empty()
    }

    /// Structural + bitwise factor equality (panels are a pure function
    /// of the factor arena, so they are implied and skipped).
    pub fn bits_eq(&self, o: &FarField) -> bool {
        self.rows == o.rows
            && self.cols == o.cols
            && self.tgt_leaves == o.tgt_leaves
            && self.blocks == o.blocks
            && self.tasks == o.tasks
            && self.factors.len() == o.factors.len()
            && self
                .factors
                .iter()
                .zip(&o.factors)
                .all(|(a, b)| a.to_bits() == b.to_bits())
    }

    /// Index-space area covered by far blocks.
    pub fn coverage(&self) -> u64 {
        self.blocks.iter().map(|b| b.area()).sum()
    }

    /// Compressed far-field storage in bytes (factor arena; the panel
    /// mirror doubles it — reported separately because the panel copy is
    /// an optional SIMD amenity, not the representation).
    pub fn far_bytes(&self) -> u64 {
        self.factors.len() as u64 * 4
    }

    /// What the same far blocks would cost stored dense.
    pub fn dense_far_bytes(&self) -> u64 {
        self.coverage() * 4
    }

    pub fn low_rank_blocks(&self) -> usize {
        self.blocks.iter().filter(|b| !b.is_dense()).count()
    }

    pub fn dense_fallback_blocks(&self) -> usize {
        self.blocks.iter().filter(|b| b.is_dense()).count()
    }

    pub fn max_rank(&self) -> u32 {
        self.blocks.iter().map(|b| b.rank).max().unwrap_or(0)
    }

    /// Mean rank over low-rank blocks.
    pub fn mean_rank(&self) -> f64 {
        let lr = self.low_rank_blocks();
        if lr == 0 {
            return 0.0;
        }
        let sum: u64 = self.blocks.iter().filter(|b| !b.is_dense()).map(|b| b.rank as u64).sum();
        sum as f64 / lr as f64
    }

    /// (rank, block count) pairs over low-rank blocks, ascending rank —
    /// the rank histogram the farfield bench records.
    pub fn rank_histogram(&self) -> Vec<(u32, u32)> {
        let mut counts = std::collections::BTreeMap::new();
        for b in self.blocks.iter().filter(|b| !b.is_dense()) {
            *counts.entry(b.rank).or_insert(0u32) += 1;
        }
        counts.into_iter().collect()
    }

    /// Stats line for logs/benches.
    pub fn describe(&self) -> String {
        let dense = self.dense_far_bytes();
        let ratio = if dense == 0 {
            0.0
        } else {
            self.far_bytes() as f64 / dense as f64
        };
        format!(
            "far_blocks={} lowrank={} dense_fallback={} mean_rank={:.1} max_rank={} \
             bytes={} ({:.1}% of dense far field)",
            self.blocks.len(),
            self.low_rank_blocks(),
            self.dense_fallback_blocks(),
            self.mean_rank(),
            self.max_rank(),
            self.far_bytes(),
            ratio * 100.0
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::csb::panel::PANEL_MR;
    use crate::data::synth::SynthSpec;
    use crate::hmat::admissible::partition;
    use crate::tree::boxtree::BoxTree;

    fn setup(n: usize) -> (Vec<f32>, Partition, FarField) {
        let ds = SynthSpec::blobs(n, 3, 4, 21).generate();
        let tree = BoxTree::build(&ds, 8, 24);
        let coords = ds.permuted(&tree.perm).raw().to_vec();
        let part = partition(&tree, 32, 1.0);
        let far = FarField::build(&part, &coords, 3, 0.5, 1e-3, 2);
        (coords, part, far)
    }

    #[test]
    fn arenas_cover_every_block_disjointly() {
        let (_, part, far) = setup(500);
        assert_eq!(far.blocks.len(), part.far.len());
        let mut regions: Vec<(usize, usize)> = Vec::new();
        for b in &far.blocks {
            let rn = b.rows.len();
            let cn = b.cols.len();
            match b.kind {
                FarKind::LowRank { u_off, vt_off, .. } => {
                    let r = b.rank as usize;
                    regions.push((u_off as usize, rn * r));
                    regions.push((vt_off as usize, r * cn));
                }
                FarKind::Dense { off, .. } => regions.push((off as usize, rn * cn)),
            }
        }
        let total: usize = regions.iter().map(|&(_, l)| l).sum();
        assert_eq!(total, far.factors.len(), "factor arena exactly tiled");
        regions.sort_unstable();
        let mut expect = 0usize;
        for (off, len) in regions {
            assert_eq!(off, expect, "gap or overlap in the factor arena");
            expect = off + len;
        }
    }

    #[test]
    fn panels_mirror_factor_regions() {
        let (_, _, far) = setup(400);
        let panel = far.panels.as_slice();
        let check = |src: &[f32], nr: usize, nc: usize, poff: usize| {
            for r in 0..nr {
                for c in 0..nc {
                    let idx = (r / PANEL_MR) * nc * PANEL_MR + c * PANEL_MR + (r % PANEL_MR);
                    assert_eq!(panel[poff + idx].to_bits(), src[r * nc + c].to_bits());
                }
            }
        };
        for b in &far.blocks {
            let rn = b.rows.len();
            let cn = b.cols.len();
            match b.kind {
                FarKind::LowRank {
                    u_off,
                    vt_off,
                    u_poff,
                    vt_poff,
                } => {
                    let (uo, vo) = (u_off as usize, vt_off as usize);
                    let r = b.rank as usize;
                    check(&far.factors[uo..uo + rn * r], rn, r, u_poff as usize);
                    check(&far.factors[vo..vo + r * cn], r, cn, vt_poff as usize);
                }
                FarKind::Dense { off, poff } => {
                    let o = off as usize;
                    check(&far.factors[o..o + rn * cn], rn, cn, poff as usize);
                }
            }
        }
    }

    #[test]
    fn build_bitidentical_across_thread_counts() {
        let ds = SynthSpec::blobs(600, 3, 5, 33).generate();
        let tree = BoxTree::build(&ds, 8, 24);
        let coords = ds.permuted(&tree.perm).raw().to_vec();
        let part = partition(&tree, 32, 1.0);
        let ref1 = FarField::build(&part, &coords, 3, 0.7, 1e-3, 1);
        for threads in [2usize, 8] {
            let f = FarField::build(&part, &coords, 3, 0.7, 1e-3, threads);
            assert_eq!(f.blocks, ref1.blocks, "threads={threads}");
            assert_eq!(f.factors.len(), ref1.factors.len());
            assert!(
                f.factors.iter().zip(&ref1.factors).all(|(a, b)| a.to_bits() == b.to_bits()),
                "factor arena differs at threads={threads}"
            );
            assert!(
                f.panels
                    .as_slice()
                    .iter()
                    .zip(ref1.panels.as_slice())
                    .all(|(a, b)| a.to_bits() == b.to_bits()),
                "panel arena differs at threads={threads}"
            );
            assert_eq!(f.tasks, ref1.tasks);
        }
    }

    #[test]
    fn compression_beats_dense_on_clustered_data() {
        // Production-ish block size: small blocks barely compress
        // ((rn+cn)·r vs rn·cn needs rn,cn >> r), so test at cap 128.
        let ds = SynthSpec::blobs(800, 3, 4, 21).generate();
        let tree = BoxTree::build(&ds, 8, 24);
        let coords = ds.permuted(&tree.perm).raw().to_vec();
        let part = partition(&tree, 128, 1.0);
        let far = FarField::build(&part, &coords, 3, 0.5, 1e-3, 2);
        assert!(!far.is_empty());
        assert!(
            far.far_bytes() * 2 < far.dense_far_bytes(),
            "expected <1/2 of dense far bytes: {}",
            far.describe()
        );
        let hist = far.rank_histogram();
        let total: u32 = hist.iter().map(|&(_, c)| c).sum();
        assert_eq!(total as usize, far.low_rank_blocks());
    }

    #[test]
    fn tasks_cover_exactly_nonempty_leaves() {
        let (_, _, far) = setup(500);
        let nonempty: usize = far.by_target.iter().filter(|l| !l.is_empty()).count();
        assert_eq!(far.tasks.len(), nonempty);
        for &tl in &far.tasks {
            assert!(!far.by_target[tl as usize].is_empty());
        }
    }
}
