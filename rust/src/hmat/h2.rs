//! H² far field: nested cluster bases + transfer matrices + skeleton
//! couplings (the O(n)-class refinement of the per-block ACA store).
//!
//! Where [`crate::hmat::store::FarField`] factors every admissible block
//! independently (`U·Vᵀ` per block — O(n log n) bytes with a large
//! constant), the H² representation shares one **cluster basis** per cut
//! leaf across every block that touches it, and compresses source nodes
//! above the cut through small **transfer matrices** over the union of
//! their children's skeletons (the nested-basis construction of
//! arXiv 2206.01885, seeded from partial-pivot ACA pivots):
//!
//! * **leaf basis** — run [`crate::hmat::aca::aca_core`] on the leaf's
//!   rows against a stride-sample of its far field `F(l)`; the accepted
//!   pivot rows `I` are the leaf *skeleton* and the basis is the cross
//!   interpolation `P = A[:,J]·inv(A[I,J])` (computed in f64, skeleton
//!   rows forced to exact identity);
//! * **source node** — for an admissible source span covering several cut
//!   leaves, re-compress the concatenation `Iu` of its leaves' skeletons
//!   against the node's own far sample: the accepted pivots select the
//!   node skeleton `Iu[I]` and the transfer is the same cross
//!   interpolation, stored transposed (`Tᵀ`) for the upward sweep;
//! * **coupling** — each far block stores only the skeleton-to-skeleton
//!   kernel `S = K(skel_t, skel_s)` (`r_t x r_s`).
//!
//! The apply is the classic three-phase sweep — forward gather
//! `x̂_l = P_lᵀ·x[l]`, upward transfer `x̂_node = Tᵀ·concat(x̂_leaves)`,
//! then per-target coupling + one backward scatter
//! `y[t] += P_t·Σ_s S_ts·x̂_s` — all through the dispatched
//! `csb::kernel` GEMMs under the repo's disjoint-ownership discipline,
//! so the result is **bit-identical across thread counts**.
//!
//! Mixed precision: with [`Precision::Bf16`], every factor matrix whose
//! round-to-nearest-even bf16 image stays within `0.25·tol` relative
//! Frobenius error is stored as bf16-in-u16 (half the bytes); f32
//! factors additionally get packed AVX2 panels.  Accumulation stays in
//! f32 GEMMs with the same f64 norm/test discipline as the ACA path.

use crate::csb::hier::Span;
use crate::csb::panel::{pack_panel, panel_len, AlignedF32, NO_PANEL};
use crate::csb::update::SideDelta;
use crate::csb::kernel::{dense_gemm_acc, Dispatch};
use crate::hmat::aca::{aca_core, GaussGen};
use crate::hmat::admissible::Partition;
use crate::hmat::apply::far_gemm;
use crate::hmat::update::cut_ordinals;
use crate::hmat::{FarFieldMode, Precision};
use crate::obs::{self, counters, Counter};
use crate::par::pool::{SendPtr, ThreadPool};
use std::sync::Mutex;

/// Cap on the stride-sampled far-field column sample per cluster: large
/// enough that the sample spans every admissible direction, small enough
/// that basis construction stays O(leaf · cap).
pub const F_SAMPLE_CAP: usize = 384;

/// Round-to-nearest-even bf16 encoding of an f32 (top 16 bits + RNE).
#[inline]
pub fn bf16_encode(v: f32) -> u16 {
    let u = v.to_bits() as u64;
    ((u + 0x7FFF + ((u >> 16) & 1)) >> 16) as u16
}

/// Decode a bf16-in-u16 back to f32 (exact: bf16 ⊂ f32).
#[inline]
pub fn bf16_decode(b: u16) -> f32 {
    f32::from_bits((b as u32) << 16)
}

/// Locator of one factor matrix: `off` indexes the f32 arena (`bf16 =
/// false`, with a packed panel at `poff` unless [`NO_PANEL`]) or the u16
/// arena (`bf16 = true`, never panelled).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Fac {
    pub off: u32,
    pub poff: u32,
    pub bf16: bool,
}

/// One cut leaf's cluster basis: `Pᵀ` (`rank x len`, forward gather) and
/// `P` (`len x rank`, backward scatter) share one precision decision.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct BasisLoc {
    pub rank: u32,
    pub pt: Fac,
    pub p: Fac,
}

/// One admissible source node covering several consecutive cut leaves.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SrcNode {
    pub span: Span,
    /// First constituent cut-leaf ordinal (leaves are consecutive).
    pub first_leaf: u32,
    pub nleaves: u32,
    pub rank: u32,
    /// `Tᵀ` (`rank x iu_len`): upward transfer over the concatenated
    /// child skeletons.
    pub t: Fac,
    /// Length of the concatenated child-skeleton union.
    pub iu_len: u32,
    /// Offset of this node's `rank` global skeleton indices in
    /// [`H2Field::node_skel`].
    pub skel_off: u32,
    /// This node's coefficient slot (after every leaf slot).
    pub coeff_off: u32,
}

/// Source side of a far block: a single cut leaf's cluster, or a
/// [`SrcNode`] above the cut.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SrcRef {
    Leaf(u32),
    Node(u32),
}

/// One far block: skeleton coupling `S` (`r_t x r_s`) between target
/// leaf `tleaf` and its source cluster.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct H2Block {
    pub tleaf: u32,
    pub rows: Span,
    pub cols: Span,
    pub src: SrcRef,
    pub s: Fac,
    pub r_t: u32,
    pub r_s: u32,
}

impl H2Block {
    pub fn area(&self) -> u64 {
        self.rows.len() as u64 * self.cols.len() as u64
    }
}

/// The H² far field of a full-kernel operator.
#[derive(Clone, Debug)]
pub struct H2Field {
    pub rows: usize,
    pub cols: usize,
    /// Target-leaf blocking (identical to the near `HierCsb`'s cut).
    pub tgt_leaves: Vec<Span>,
    /// Leaf-local skeleton row indices, concatenated per leaf.
    pub skel: Vec<u32>,
    /// Per-leaf exclusive-scan offsets into `skel` (`nleaf + 1`).
    pub skel_off: Vec<u32>,
    /// Per-leaf basis locators.
    pub basis: Vec<BasisLoc>,
    /// Source nodes above the cut, sorted by span.
    pub nodes: Vec<SrcNode>,
    /// Global skeleton indices of every node, concatenated.
    pub node_skel: Vec<u32>,
    /// Far blocks in partition (traversal) order.
    pub blocks: Vec<H2Block>,
    /// Per target leaf: indices into `blocks`.
    pub by_target: Vec<Vec<u32>>,
    /// Non-empty target-leaf ordinals, heaviest first by coupling flops.
    pub tasks: Vec<u32>,
    /// f32 factor arena (scan-ordered).
    pub f32a: Vec<f32>,
    /// bf16-in-u16 factor arena (scan-ordered).
    pub bf16a: Vec<u16>,
    /// Packed panels of the f32 factors.
    pub panels: AlignedF32,
    /// Per-leaf coefficient slot offsets (exclusive scan of basis ranks;
    /// leaf slots are tightly packed in leaf order so a node's input
    /// concat is one contiguous slice).
    pub coeff_off: Vec<u32>,
    /// Total coefficient slots (leaves + nodes) per RHS column.
    pub coeff_len: usize,
    pub eta: f32,
    pub tol: f32,
    pub precision: Precision,
}

/// Deterministic stride-sample of the union of `spans` (merged, sorted),
/// capped at `cap` indices.
fn sample_indices(spans: &mut Vec<Span>, cap: usize) -> Vec<u32> {
    if spans.is_empty() {
        return Vec::new();
    }
    spans.sort_unstable_by_key(|s| (s.lo, s.hi));
    let mut merged: Vec<Span> = Vec::new();
    for &s in spans.iter() {
        if let Some(last) = merged.last_mut() {
            if s.lo <= last.hi {
                last.hi = last.hi.max(s.hi);
                continue;
            }
        }
        merged.push(s);
    }
    let total: usize = merged.iter().map(|s| s.len()).sum();
    let stride = total.div_ceil(cap).max(1);
    let mut out = Vec::with_capacity(total.div_ceil(stride));
    let mut c = 0usize;
    for s in &merged {
        for j in s.lo..s.hi {
            if c % stride == 0 {
                out.push(j);
            }
            c += 1;
        }
    }
    out
}

/// Ordinal of the cut leaf starting exactly at global index `lo`.
fn leaf_at(leaves: &[Span], lo: u32) -> usize {
    let i = leaves.partition_point(|sp| sp.lo < lo);
    debug_assert!(i < leaves.len() && leaves[i].lo == lo, "span off the cut grid");
    i
}

/// Per-leaf far-field sample `F(l)`: source spans of blocks targeting
/// `l`, plus target spans of blocks whose source span contains `l` —
/// merged and stride-sampled.  Pure function of the partition.
pub(crate) fn leaf_samples(part: &Partition) -> Vec<Vec<u32>> {
    let nleaf = part.leaves.len();
    let mut lists: Vec<Vec<Span>> = vec![Vec::new(); nleaf];
    for fb in &part.far {
        lists[fb.tleaf as usize].push(fb.cols);
        let mut li = leaf_at(&part.leaves, fb.cols.lo);
        while li < nleaf && part.leaves[li].hi <= fb.cols.hi {
            lists[li].push(fb.rows);
            li += 1;
        }
    }
    lists
        .into_iter()
        .map(|mut s| sample_indices(&mut s, F_SAMPLE_CAP))
        .collect()
}

/// Source-node directory: distinct multi-leaf source spans (sorted), the
/// target row spans each one must cover (its far sample), and every far
/// block's resolved [`SrcRef`].
fn node_directory(part: &Partition) -> (Vec<Span>, Vec<Vec<Span>>, Vec<SrcRef>) {
    let leaves = &part.leaves;
    let mut nspans: Vec<(u32, u32)> = part
        .far
        .iter()
        .filter_map(|fb| {
            let fl = leaf_at(leaves, fb.cols.lo);
            (leaves[fl].hi != fb.cols.hi).then_some((fb.cols.lo, fb.cols.hi))
        })
        .collect();
    nspans.sort_unstable();
    nspans.dedup();
    let mut fspans: Vec<Vec<Span>> = vec![Vec::new(); nspans.len()];
    let src_of: Vec<SrcRef> = part
        .far
        .iter()
        .map(|fb| {
            let fl = leaf_at(leaves, fb.cols.lo);
            if leaves[fl].hi == fb.cols.hi {
                SrcRef::Leaf(fl as u32)
            } else {
                let ni = nspans
                    .binary_search(&(fb.cols.lo, fb.cols.hi))
                    .expect("node span missing from directory");
                fspans[ni].push(fb.rows);
                SrcRef::Node(ni as u32)
            }
        })
        .collect();
    let spans = nspans.into_iter().map(|(lo, hi)| Span { lo, hi }).collect();
    (spans, fspans, src_of)
}

/// Cross-interpolation basis `P = A[:,J]·inv(A[I,J])` (row-major
/// `rn x r`, f32) computed in f64 via one LU of `A[I,J]ᵀ` with partial
/// pivoting, skeleton rows forced to exact identity.  `None` when the
/// pivot system is numerically singular (caller falls back to the exact
/// identity basis).  Serial and a pure function of its inputs.
fn cross_basis(
    gen: &GaussGen,
    row_of: impl Fn(usize) -> usize,
    rn: usize,
    samples: &[u32],
    i_piv: &[u32],
    j_piv: &[u32],
) -> Option<Vec<f32>> {
    let r = i_piv.len();
    // M = A[I,J]ᵀ row-major: M[a][b] = A(I[b], J[a]).
    let mut m = vec![0.0f64; r * r];
    for a in 0..r {
        for b in 0..r {
            m[a * r + b] =
                gen.entry_f64(row_of(i_piv[b] as usize), samples[j_piv[a] as usize] as usize);
        }
    }
    // In-place LU with partial pivoting through a row permutation.
    let mut perm: Vec<usize> = (0..r).collect();
    for k in 0..r {
        let mut p = k;
        let mut best = m[perm[k] * r + k].abs();
        for cand in k + 1..r {
            let v = m[perm[cand] * r + k].abs();
            if v > best {
                best = v;
                p = cand;
            }
        }
        if !(best > 1e-300) {
            return None;
        }
        perm.swap(k, p);
        let pr = perm[k];
        for cand in k + 1..r {
            let cr = perm[cand];
            let f = m[cr * r + k] / m[pr * r + k];
            m[cr * r + k] = f;
            for c in k + 1..r {
                m[cr * r + c] -= f * m[pr * r + c];
            }
        }
    }
    // Solve M·y = A[i,J]ᵀ per target row.
    let mut out = vec![0.0f32; rn * r];
    let mut rhs = vec![0.0f64; r];
    let mut y = vec![0.0f64; r];
    for i in 0..rn {
        for a in 0..r {
            rhs[a] = gen.entry_f64(row_of(i), samples[j_piv[a] as usize] as usize);
        }
        for a in 0..r {
            let mut s = rhs[perm[a]];
            for b in 0..a {
                s -= m[perm[a] * r + b] * y[b];
            }
            y[a] = s;
        }
        for a in (0..r).rev() {
            let mut s = y[a];
            for b in a + 1..r {
                s -= m[perm[a] * r + b] * y[b];
            }
            y[a] = s / m[perm[a] * r + a];
        }
        for a in 0..r {
            out[i * r + a] = y[a] as f32;
        }
    }
    // Exact interpolation property at the skeleton rows.
    for (k, &ip) in i_piv.iter().enumerate() {
        let row = &mut out[ip as usize * r..(ip as usize + 1) * r];
        row.fill(0.0);
        row[k] = 1.0;
    }
    Some(out)
}

/// One leaf's computed (or lifted) cluster basis: leaf-local skeleton
/// rows, rank, and the row-major `len x rank` interpolation matrix.
#[derive(Clone, Debug, Default, PartialEq)]
pub(crate) struct LeafBasis {
    pub skel: Vec<u32>,
    pub rank: usize,
    pub p: Vec<f32>,
}

/// Compute one leaf's basis from scratch: ACA against the far sample for
/// the skeleton, cross interpolation for `P`, identity fallback when ACA
/// bails to dense or the pivot system is singular.
fn leaf_basis(gen: &GaussGen, sp: Span, samples: &[u32], tol: f32) -> LeafBasis {
    let rn = sp.len();
    if samples.is_empty() || rn == 0 {
        return LeafBasis::default();
    }
    let identity = || {
        let mut p = vec![0.0f32; rn * rn];
        for i in 0..rn {
            p[i * rn + i] = 1.0;
        }
        LeafBasis {
            skel: (0..rn as u32).collect(),
            rank: rn,
            p,
        }
    };
    let entry = |i: usize, j: usize| gen.entry(sp.lo as usize + i, samples[j] as usize);
    let Some(b) = aca_core(entry, rn, samples.len(), tol) else {
        return identity();
    };
    if b.rank == 0 {
        // Every sampled far entry underflows: the cluster contributes
        // nothing to the far field at f32 resolution.
        return LeafBasis::default();
    }
    match cross_basis(gen, |i| sp.lo as usize + i, rn, samples, &b.row_piv, &b.col_piv) {
        Some(p) => LeafBasis {
            skel: b.row_piv,
            rank: b.rank,
            p,
        },
        None => identity(),
    }
}

/// One source node's computed transfer: skeleton positions into the
/// child-skeleton union, rank, and `Tᵀ` (`rank x iu_len`, row-major).
#[derive(Clone, Debug, Default)]
struct NodeBuild {
    skel_global: Vec<u32>,
    rank: usize,
    tt: Vec<f32>,
}

fn node_build(gen: &GaussGen, iu: &[u32], samples: &[u32], tol: f32) -> NodeBuild {
    let iu_len = iu.len();
    if iu_len == 0 || samples.is_empty() {
        return NodeBuild::default();
    }
    let identity = || {
        let mut tt = vec![0.0f32; iu_len * iu_len];
        for i in 0..iu_len {
            tt[i * iu_len + i] = 1.0;
        }
        NodeBuild {
            skel_global: iu.to_vec(),
            rank: iu_len,
            tt,
        }
    };
    let entry = |i: usize, j: usize| gen.entry(iu[i] as usize, samples[j] as usize);
    let Some(b) = aca_core(entry, iu_len, samples.len(), tol) else {
        return identity();
    };
    if b.rank == 0 {
        return NodeBuild::default();
    }
    match cross_basis(gen, |i| iu[i] as usize, iu_len, samples, &b.row_piv, &b.col_piv) {
        Some(t) => {
            // Transpose `t` (`iu_len x rank`) into the stored `Tᵀ`.
            let r = b.rank;
            let mut tt = vec![0.0f32; r * iu_len];
            for i in 0..iu_len {
                for a in 0..r {
                    tt[a * iu_len + i] = t[i * r + a];
                }
            }
            NodeBuild {
                skel_global: b.row_piv.iter().map(|&p| iu[p as usize]).collect(),
                rank: r,
                tt,
            }
        }
        None => identity(),
    }
}

/// Per-factor bf16 admission: the RNE-rounded image must stay within
/// `0.25·tol` relative Frobenius error (computed in f64).
fn quant_ok(m: &[f32], tol: f32) -> bool {
    let mut err2 = 0.0f64;
    let mut n2 = 0.0f64;
    for &v in m {
        let q = bf16_decode(bf16_encode(v)) as f64;
        let vd = v as f64;
        err2 += (vd - q) * (vd - q);
        n2 += vd * vd;
    }
    err2.sqrt() <= 0.25 * tol as f64 * n2.sqrt()
}

/// Constituent-leaf metadata of one source node.
struct NodeMeta {
    first: usize,
    nl: usize,
    /// Concatenated child skeletons as global indices.
    iu: Vec<u32>,
}

impl H2Field {
    /// Compress `part`'s far blocks into nested cluster bases over
    /// tree-ordered `coords` (row-major `n x d`).  `threads = 0` means
    /// the machine default; the result is bit-identical across thread
    /// counts (module docs).
    #[allow(clippy::too_many_arguments)]
    pub fn build(
        part: &Partition,
        coords: &[f32],
        d: usize,
        inv_h2: f32,
        tol: f32,
        precision: Precision,
        threads: usize,
    ) -> H2Field {
        obs::span!("hmat.h2.build");
        assert_eq!(coords.len(), part.n * d);
        let pool = ThreadPool::new_or_default(threads);
        let plan: Vec<Option<LeafBasis>> = vec![None; part.leaves.len()];
        Self::build_impl(part, coords, d, inv_h2, tol, precision, &pool, &plan)
    }

    /// The shared build body: leaf bases (from `plan` where lifted, from
    /// scratch otherwise), node transfers, couplings, precision
    /// selection, and the scan + parallel arena fill.  A pure function of
    /// its inputs at any thread count.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn build_impl(
        part: &Partition,
        coords: &[f32],
        d: usize,
        inv_h2: f32,
        tol: f32,
        precision: Precision,
        pool: &ThreadPool,
        plan: &[Option<LeafBasis>],
    ) -> H2Field {
        let gen = GaussGen { coords, d, inv_h2 };
        let nleaf = part.leaves.len();
        let leaves = &part.leaves;
        assert_eq!(plan.len(), nleaf);

        // Pass A — far samples + source-node directory (serial, cheap).
        let samples = leaf_samples(part);
        let (nspans, nfspans, src_of) = node_directory(part);

        // Pass B — leaf bases (order-preserving parallel map).
        let basis_span = obs::trace::SpanGuard::enter("hmat.h2.basis");
        let lidx: Vec<usize> = (0..nleaf).collect();
        let bases: Vec<LeafBasis> = pool.map(&lidx, |&l| match &plan[l] {
            Some(b) => b.clone(),
            None => leaf_basis(&gen, leaves[l], &samples[l], tol),
        });
        drop(basis_span);

        // Pass C — source-node transfers over the child-skeleton unions.
        let transfer_span = obs::trace::SpanGuard::enter("hmat.h2.transfer");
        let metas: Vec<NodeMeta> = nspans
            .iter()
            .map(|sp| {
                let first = leaf_at(leaves, sp.lo);
                let mut nl = 0usize;
                let mut hi = sp.lo;
                while hi < sp.hi {
                    hi = leaves[first + nl].hi;
                    nl += 1;
                }
                debug_assert_eq!(hi, sp.hi, "node span off the cut grid");
                let mut iu = Vec::new();
                for li in first..first + nl {
                    for &s in &bases[li].skel {
                        iu.push(leaves[li].lo + s);
                    }
                }
                NodeMeta { first, nl, iu }
            })
            .collect();
        let nidx: Vec<usize> = (0..nspans.len()).collect();
        let nbuilds: Vec<NodeBuild> = pool.map(&nidx, |&ni| {
            let mut fs = nfspans[ni].clone();
            let fsamp = sample_indices(&mut fs, F_SAMPLE_CAP);
            node_build(&gen, &metas[ni].iu, &fsamp, tol)
        });
        drop(transfer_span);

        // Pass D — skeleton-to-skeleton couplings (partition order).
        let coupling_span = obs::trace::SpanGuard::enter("hmat.h2.coupling");
        let bidx: Vec<usize> = (0..part.far.len()).collect();
        let couplings: Vec<Vec<f32>> = pool.map(&bidx, |&t| {
            let fb = &part.far[t];
            let tb = &bases[fb.tleaf as usize];
            let sglob: Vec<u32> = match src_of[t] {
                SrcRef::Leaf(sl) => bases[sl as usize]
                    .skel
                    .iter()
                    .map(|&s| leaves[sl as usize].lo + s)
                    .collect(),
                SrcRef::Node(ni) => nbuilds[ni as usize].skel_global.clone(),
            };
            let (rt, rs) = (tb.rank, sglob.len());
            let mut s = vec![0.0f32; rt * rs];
            for i in 0..rt {
                let gi = (fb.rows.lo + tb.skel[i]) as usize;
                for (j, &gj) in sglob.iter().enumerate() {
                    s[i * rs + j] = gen.entry(gi, gj as usize);
                }
            }
            s
        });
        drop(coupling_span);

        // Pass E — precision selection, exclusive scan, parallel fill.
        let fill_span = obs::trace::SpanGuard::enter("hmat.h2.fill");
        let bf16_on = precision == Precision::Bf16;
        struct Scan {
            f: usize,
            b: usize,
            p: usize,
        }
        impl Scan {
            fn fac(&mut self, nr: usize, nc: usize, q: bool) -> Fac {
                if q {
                    let off = self.b as u32;
                    self.b += nr * nc;
                    Fac {
                        off,
                        poff: NO_PANEL,
                        bf16: true,
                    }
                } else {
                    let off = self.f as u32;
                    self.f += nr * nc;
                    let poff = self.p as u32;
                    self.p += panel_len(nr, nc);
                    Fac {
                        off,
                        poff,
                        bf16: false,
                    }
                }
            }
        }
        let mut sc = Scan { f: 0, b: 0, p: 0 };

        let mut basis_locs: Vec<BasisLoc> = Vec::with_capacity(nleaf);
        for b in &bases {
            if b.rank == 0 {
                basis_locs.push(BasisLoc::default());
                continue;
            }
            let rn = b.p.len() / b.rank;
            // One decision per leaf: P and Pᵀ hold the same values.
            let q = bf16_on && quant_ok(&b.p, tol);
            let pt = sc.fac(b.rank, rn, q);
            let p = sc.fac(rn, b.rank, q);
            basis_locs.push(BasisLoc {
                rank: b.rank as u32,
                pt,
                p,
            });
        }

        // Leaf coefficient slots: tightly packed in leaf order, so the
        // input concat of any node is one contiguous coefficient slice.
        let mut coeff_off: Vec<u32> = Vec::with_capacity(nleaf);
        let mut coff = 0u32;
        for b in &bases {
            coeff_off.push(coff);
            coff += b.rank as u32;
        }

        let mut nodes: Vec<SrcNode> = Vec::with_capacity(nspans.len());
        let mut node_skel: Vec<u32> = Vec::new();
        let mut transfer_bytes = 0u64;
        for (ni, nb) in nbuilds.iter().enumerate() {
            let iu_len = metas[ni].iu.len();
            let q = bf16_on && nb.rank > 0 && quant_ok(&nb.tt, tol);
            let t = if nb.rank == 0 {
                Fac::default()
            } else {
                sc.fac(nb.rank, iu_len, q)
            };
            transfer_bytes += nb.tt.len() as u64 * if q { 2 } else { 4 };
            let skoff = node_skel.len() as u32;
            node_skel.extend_from_slice(&nb.skel_global);
            nodes.push(SrcNode {
                span: nspans[ni],
                first_leaf: metas[ni].first as u32,
                nleaves: metas[ni].nl as u32,
                rank: nb.rank as u32,
                t,
                iu_len: iu_len as u32,
                skel_off: skoff,
                coeff_off: coff,
            });
            coff += nb.rank as u32;
        }
        let coeff_len = coff as usize;

        let mut blocks: Vec<H2Block> = Vec::with_capacity(part.far.len());
        for (t, fb) in part.far.iter().enumerate() {
            let rt = bases[fb.tleaf as usize].rank;
            let rs = match src_of[t] {
                SrcRef::Leaf(sl) => bases[sl as usize].rank,
                SrcRef::Node(ni) => nbuilds[ni as usize].rank,
            };
            let q = bf16_on && rt * rs > 0 && quant_ok(&couplings[t], tol);
            let s = if rt * rs == 0 {
                Fac::default()
            } else {
                sc.fac(rt, rs, q)
            };
            blocks.push(H2Block {
                tleaf: fb.tleaf,
                rows: fb.rows,
                cols: fb.cols,
                src: src_of[t],
                s,
                r_t: rt as u32,
                r_s: rs as u32,
            });
        }
        assert!(
            sc.f <= u32::MAX as usize && sc.b <= u32::MAX as usize && sc.p <= u32::MAX as usize,
            "h2 factor arena exceeds u32 offsets"
        );

        enum Job {
            LeafPt(u32),
            LeafP(u32),
            NodeT(u32),
            BlockS(u32),
        }
        let mut jobs: Vec<Job> = Vec::new();
        for l in 0..nleaf {
            if bases[l].rank > 0 {
                jobs.push(Job::LeafPt(l as u32));
                jobs.push(Job::LeafP(l as u32));
            }
        }
        for (ni, nd) in nodes.iter().enumerate() {
            if nd.rank > 0 {
                jobs.push(Job::NodeT(ni as u32));
            }
        }
        for (t, b) in blocks.iter().enumerate() {
            if b.r_t * b.r_s > 0 {
                jobs.push(Job::BlockS(t as u32));
            }
        }

        let mut f32a = vec![0.0f32; sc.f];
        let mut bf16a = vec![0u16; sc.b];
        let mut panels = AlignedF32::zeroed(sc.p);
        {
            let fp = SendPtr(f32a.as_mut_ptr());
            let bp = SendPtr(bf16a.as_mut_ptr());
            let pp = SendPtr(panels.as_mut_slice().as_mut_ptr());
            let (fpr, bpr, ppr) = (&fp, &bp, &pp);
            let jobs_ref = &jobs;
            let bases_ref = &bases;
            let nbuilds_ref = &nbuilds;
            let locs_ref = &basis_locs;
            let nodes_ref = &nodes;
            let blocks_ref = &blocks;
            let coup_ref = &couplings;
            pool.for_each_chunked(jobs_ref.len(), 4, |t| {
                // SAFETY: every job's arena regions are disjoint by the
                // exclusive scan; this task writes only its own regions.
                let write = |vals: &[f32], nr: usize, nc: usize, fac: Fac| {
                    debug_assert_eq!(vals.len(), nr * nc);
                    if fac.bf16 {
                        let dst: &mut [u16] = unsafe {
                            std::slice::from_raw_parts_mut(bpr.0.add(fac.off as usize), nr * nc)
                        };
                        for (dv, &v) in dst.iter_mut().zip(vals) {
                            *dv = bf16_encode(v);
                        }
                    } else {
                        let dst: &mut [f32] = unsafe {
                            std::slice::from_raw_parts_mut(fpr.0.add(fac.off as usize), nr * nc)
                        };
                        dst.copy_from_slice(vals);
                        let pl = panel_len(nr, nc);
                        let pdst: &mut [f32] = unsafe {
                            std::slice::from_raw_parts_mut(ppr.0.add(fac.poff as usize), pl)
                        };
                        pack_panel(vals, nr, nc, pdst);
                    }
                };
                match jobs_ref[t] {
                    Job::LeafPt(l) => {
                        let b = &bases_ref[l as usize];
                        let r = b.rank;
                        let rn = b.p.len() / r;
                        let mut pt = vec![0.0f32; r * rn];
                        for i in 0..rn {
                            for a in 0..r {
                                pt[a * rn + i] = b.p[i * r + a];
                            }
                        }
                        write(&pt, r, rn, locs_ref[l as usize].pt);
                    }
                    Job::LeafP(l) => {
                        let b = &bases_ref[l as usize];
                        let r = b.rank;
                        let rn = b.p.len() / r;
                        write(&b.p, rn, r, locs_ref[l as usize].p);
                    }
                    Job::NodeT(ni) => {
                        let nd = &nodes_ref[ni as usize];
                        write(
                            &nbuilds_ref[ni as usize].tt,
                            nd.rank as usize,
                            nd.iu_len as usize,
                            nd.t,
                        );
                    }
                    Job::BlockS(t2) => {
                        let b = &blocks_ref[t2 as usize];
                        write(&coup_ref[t2 as usize], b.r_t as usize, b.r_s as usize, b.s);
                    }
                }
            });
        }
        drop(fill_span);

        let mut skel: Vec<u32> = Vec::new();
        let mut skel_off: Vec<u32> = Vec::with_capacity(nleaf + 1);
        skel_off.push(0);
        for b in &bases {
            skel.extend_from_slice(&b.skel);
            skel_off.push(skel.len() as u32);
        }

        let mut by_target: Vec<Vec<u32>> = vec![Vec::new(); nleaf];
        for (t, b) in blocks.iter().enumerate() {
            by_target[b.tleaf as usize].push(t as u32);
        }
        // Heaviest-first task order by coupling + scatter flops.
        let flops: Vec<u64> = (0..nleaf)
            .map(|tl| {
                let rt = bases[tl].rank as u64;
                if rt == 0 || by_target[tl].is_empty() {
                    return 0;
                }
                let coup: u64 = by_target[tl]
                    .iter()
                    .map(|&t| rt * blocks[t as usize].r_s as u64)
                    .sum();
                coup + rt * leaves[tl].len() as u64
            })
            .collect();
        let mut tasks: Vec<u32> = (0..nleaf as u32).filter(|&tl| flops[tl as usize] > 0).collect();
        tasks.sort_by_key(|&tl| (std::cmp::Reverse(flops[tl as usize]), tl));

        counters::add(Counter::H2BasisRanks, bases.iter().map(|b| b.rank as u64).sum());
        counters::add(Counter::H2TransferBytes, transfer_bytes);
        counters::add(Counter::H2CouplingBlocks, blocks.len() as u64);
        counters::add(Counter::H2F32Bytes, f32a.len() as u64 * 4);
        counters::add(Counter::H2Bf16Bytes, bf16a.len() as u64 * 2);

        H2Field {
            rows: part.n,
            cols: part.n,
            tgt_leaves: part.leaves.clone(),
            skel,
            skel_off,
            basis: basis_locs,
            nodes,
            node_skel,
            blocks,
            by_target,
            tasks,
            f32a,
            bf16a,
            panels,
            coeff_off,
            coeff_len,
            eta: part.eta,
            tol,
            precision,
        }
    }
}

impl H2Field {
    #[inline]
    fn panel(&self, poff: u32, nr: usize, nc: usize) -> &[f32] {
        let off = poff as usize;
        &self.panels.as_slice()[off..off + panel_len(nr, nc)]
    }

    /// One dispatched `y += factor · x` GEMM over an arena factor.  bf16
    /// factors decode to f32 first (the GEMM itself always runs on f32
    /// values with the usual accumulation discipline); f32 factors go
    /// through the same `far_gemm` panel dispatch as the ACA store.
    #[allow(clippy::too_many_arguments)]
    fn fac_gemm(
        &self,
        dispatch: Dispatch,
        fac: Fac,
        nr: usize,
        nc: usize,
        x: &[f32],
        k: usize,
        y: &mut [f32],
    ) {
        if nr == 0 || nc == 0 {
            return;
        }
        let off = fac.off as usize;
        if fac.bf16 {
            let dec: Vec<f32> = self.bf16a[off..off + nr * nc]
                .iter()
                .map(|&b| bf16_decode(b))
                .collect();
            dense_gemm_acc(&dec, nr, nc, x, k, y);
        } else {
            far_gemm(
                dispatch,
                &self.f32a[off..off + nr * nc],
                self.panel(fac.poff, nr, nc),
                nr,
                nc,
                x,
                k,
                y,
            );
        }
    }

    /// `y += far · x` with `k` RHS columns (`x`: `cols x k`, `y`:
    /// `rows x k`, row-major).  **Accumulates** on top of the near-field
    /// product, exactly like [`FarField::apply_acc`].  Three phases, each
    /// a pool barrier: forward gather `x̂_l = P_lᵀ·x_l`, node transfers
    /// `x̂_ν = Tᵀ_ν·concat(x̂_children)`, then per-target coupling sums
    /// `ŷ_t = Σ S·x̂_src` and one backward scatter `y_t += P_t·ŷ_t`.
    /// Bit-identical across thread counts: every phase writes disjoint
    /// regions in a fixed per-region order.
    pub fn apply_acc(
        &self,
        x: &[f32],
        k: usize,
        y: &mut [f32],
        pool: &ThreadPool,
        dispatch: Dispatch,
        scratch: &[Mutex<AlignedF32>],
    ) {
        assert!(k >= 1, "apply needs at least one RHS column");
        assert_eq!(x.len(), self.cols * k);
        assert_eq!(y.len(), self.rows * k);
        assert!(
            scratch.len() >= pool.threads,
            "need one scratch slot per pool worker"
        );
        if self.blocks.is_empty() {
            return;
        }
        obs::span!("hmat.far.apply");
        counters::add(Counter::FarApplyCalls, 1);
        // Compressed multiply-add cells across all four GEMM families —
        // flops = 2·cells·k, same convention as the ACA apply.
        let gather: u64 = self
            .basis
            .iter()
            .zip(&self.tgt_leaves)
            .map(|(b, sp)| b.rank as u64 * sp.len() as u64)
            .sum();
        let transfer: u64 = self.nodes.iter().map(|n| n.rank as u64 * n.iu_len as u64).sum();
        let coupling: u64 = self.blocks.iter().map(|b| b.r_t as u64 * b.r_s as u64).sum();
        let backward: u64 = self
            .tasks
            .iter()
            .map(|&tl| {
                self.basis[tl as usize].rank as u64 * self.tgt_leaves[tl as usize].len() as u64
            })
            .sum();
        counters::add(
            Counter::FarGemmFlops,
            2 * (gather + transfer + coupling + backward) * k as u64,
        );

        let mut coeff = vec![0.0f32; self.coeff_len * k];

        // Phase 1 — forward gather into the leaf coefficient slots.
        {
            let cp = SendPtr(coeff.as_mut_ptr());
            let cpr = &cp;
            pool.for_each_chunked(self.tgt_leaves.len(), 1, |l| {
                let b = &self.basis[l];
                let r = b.rank as usize;
                if r == 0 {
                    return;
                }
                let sp = self.tgt_leaves[l];
                let x_seg = &x[sp.lo as usize * k..sp.hi as usize * k];
                // SAFETY: leaf coefficient slots are disjoint by the
                // exclusive scan; one task per leaf.
                let dst: &mut [f32] = unsafe {
                    std::slice::from_raw_parts_mut(
                        cpr.0.add(self.coeff_off[l] as usize * k),
                        r * k,
                    )
                };
                self.fac_gemm(dispatch, b.pt, r, sp.len(), x_seg, k, dst);
            });
        }

        // Phase 2 — node transfers.  Leaf slots are tightly packed in
        // leaf order, so each node's input is one contiguous slice; node
        // slots live strictly after all leaf slots, so a split borrow
        // separates the read and write regions.
        let leaf_coeff = match self.nodes.first() {
            Some(n0) => n0.coeff_off as usize,
            None => self.coeff_len,
        };
        if !self.nodes.is_empty() {
            let (cleaf, cnode) = coeff.split_at_mut(leaf_coeff * k);
            let np = SendPtr(cnode.as_mut_ptr());
            let npr = &np;
            let cleaf_ref = &cleaf[..];
            pool.for_each_chunked(self.nodes.len(), 1, |ni| {
                let nd = &self.nodes[ni];
                let r = nd.rank as usize;
                if r == 0 {
                    return;
                }
                let in_lo = self.coeff_off[nd.first_leaf as usize] as usize * k;
                let in_len = nd.iu_len as usize * k;
                let xin = &cleaf_ref[in_lo..in_lo + in_len];
                // SAFETY: node coefficient slots are disjoint.
                let dst: &mut [f32] = unsafe {
                    std::slice::from_raw_parts_mut(
                        npr.0.add((nd.coeff_off as usize - leaf_coeff) * k),
                        r * k,
                    )
                };
                self.fac_gemm(dispatch, nd.t, r, nd.iu_len as usize, xin, k, dst);
            });
        }

        // Phase 3 — coupling sums + backward scatter, one task per
        // non-empty target leaf (owns all writes to that leaf's rows).
        let coeff_ro = &coeff[..];
        let yp = SendPtr(y.as_mut_ptr());
        let ypr = &yp;
        pool.for_each_chunked_worker(self.tasks.len(), 1, |w, ti| {
            obs::span!("hmat.far.task");
            let tl = self.tasks[ti] as usize;
            let sp = self.tgt_leaves[tl];
            let bl = &self.basis[tl];
            let rt = bl.rank as usize;
            // SAFETY: target-leaf row spans are disjoint and each leaf is
            // owned by exactly one task; the slice covers only that span.
            let seg: &mut [f32] = unsafe {
                std::slice::from_raw_parts_mut(ypr.0.add(sp.lo as usize * k), sp.len() * k)
            };
            let mut z = scratch[w].lock().unwrap();
            let yhat = z.reset_zeroed(rt * k);
            for &t in &self.by_target[tl] {
                let b = &self.blocks[t as usize];
                let rs = b.r_s as usize;
                if rs == 0 {
                    continue;
                }
                let src_off = match b.src {
                    SrcRef::Leaf(sl) => self.coeff_off[sl as usize] as usize,
                    SrcRef::Node(ni) => self.nodes[ni as usize].coeff_off as usize,
                };
                let xhat = &coeff_ro[src_off * k..(src_off + rs) * k];
                self.fac_gemm(dispatch, b.s, rt, rs, xhat, k, yhat);
            }
            self.fac_gemm(dispatch, bl.p, sp.len(), rt, yhat, k, seg);
        });
    }
}

impl H2Field {
    pub fn is_empty(&self) -> bool {
        self.blocks.is_empty()
    }

    /// Total far-field cells covered (complement of the near coverage).
    pub fn coverage(&self) -> u64 {
        self.blocks.iter().map(|b| b.area()).sum()
    }

    /// Factor arena bytes (f32 + bf16; panels excluded, same convention
    /// as [`FarField::far_bytes`](crate::hmat::store::FarField)).
    pub fn far_bytes(&self) -> u64 {
        self.f32a.len() as u64 * 4 + self.bf16a.len() as u64 * 2
    }

    /// Bytes a dense f32 materialization of the far blocks would need.
    pub fn dense_far_bytes(&self) -> u64 {
        self.coverage() * 4
    }

    pub fn block_count(&self) -> usize {
        self.blocks.len()
    }

    pub fn src_node_count(&self) -> usize {
        self.nodes.len()
    }

    pub fn mean_basis_rank(&self) -> f64 {
        if self.basis.is_empty() {
            return 0.0;
        }
        self.basis.iter().map(|b| b.rank as f64).sum::<f64>() / self.basis.len() as f64
    }

    pub fn max_basis_rank(&self) -> usize {
        self.basis.iter().map(|b| b.rank as usize).max().unwrap_or(0)
    }

    /// Leaf-basis rank histogram (rank → leaf count), ascending.
    pub fn rank_histogram(&self) -> Vec<(usize, usize)> {
        let mut hist: Vec<(usize, usize)> = Vec::new();
        let mut ranks: Vec<usize> = self.basis.iter().map(|b| b.rank as usize).collect();
        ranks.sort_unstable();
        for r in ranks {
            match hist.last_mut() {
                Some((rr, c)) if *rr == r => *c += 1,
                _ => hist.push((r, 1)),
            }
        }
        hist
    }

    /// Number of factor matrices stored as bf16 (Pᵀ/P count as two).
    pub fn bf16_factors(&self) -> usize {
        let b = self.basis.iter().filter(|b| b.rank > 0 && b.p.bf16).count() * 2;
        let t = self.nodes.iter().filter(|n| n.rank > 0 && n.t.bf16).count();
        let s = self
            .blocks
            .iter()
            .filter(|bl| bl.r_t * bl.r_s > 0 && bl.s.bf16)
            .count();
        b + t + s
    }

    pub fn mode(&self) -> FarFieldMode {
        FarFieldMode::H2
    }

    /// Global indices of every leaf-skeleton row, stride-capped at `cap`
    /// — the rows the far-field compression itself singled out as
    /// spanning the kernel's range, i.e. the natural Nyström landmark
    /// set for preconditioning (`apps::krr`).  Deterministic.
    pub fn landmarks(&self, cap: usize) -> Vec<u32> {
        let mut lm = Vec::with_capacity(self.skel.len());
        for (l, sp) in self.tgt_leaves.iter().enumerate() {
            for &s in &self.skel[self.skel_off[l] as usize..self.skel_off[l + 1] as usize] {
                lm.push(sp.lo + s);
            }
        }
        if lm.is_empty() {
            return lm;
        }
        let stride = lm.len().div_ceil(cap.max(1)).max(1);
        lm.into_iter().step_by(stride).collect()
    }

    /// Structural + bitwise factor equality (panels are a pure function
    /// of the f32 arena, so they are implied and skipped).
    pub fn bits_eq(&self, o: &H2Field) -> bool {
        self.rows == o.rows
            && self.cols == o.cols
            && self.precision == o.precision
            && self.tgt_leaves == o.tgt_leaves
            && self.skel == o.skel
            && self.skel_off == o.skel_off
            && self.basis == o.basis
            && self.nodes == o.nodes
            && self.node_skel == o.node_skel
            && self.blocks == o.blocks
            && self.tasks == o.tasks
            && self.coeff_off == o.coeff_off
            && self.coeff_len == o.coeff_len
            && self.f32a.len() == o.f32a.len()
            && self.f32a.iter().zip(&o.f32a).all(|(a, b)| a.to_bits() == b.to_bits())
            && self.bf16a == o.bf16a
    }

    pub fn describe(&self) -> String {
        let dense = self.dense_far_bytes();
        let pct = if dense == 0 {
            0.0
        } else {
            100.0 * self.far_bytes() as f64 / dense as f64
        };
        format!(
            "far_blocks={} src_nodes={} mean_basis_rank={:.1} max_basis_rank={} bf16_factors={} bytes={} ({:.1}% of dense far field)",
            self.block_count(),
            self.src_node_count(),
            self.mean_basis_rank(),
            self.max_basis_rank(),
            self.bf16_factors(),
            self.far_bytes(),
            pct
        )
    }
}

/// Reconstruct leaf `otl`'s [`LeafBasis`] from the old arenas.  For f32
/// factors this is byte-preserving; for bf16 factors the decoded values
/// re-quantize to the identical bits (`Q(Q(x)) = Q(x)` and the re-run
/// admission test sees zero error), so [`H2Field::update`] stays
/// bit-identical to a from-scratch build either way.
fn lift_basis(old: &H2Field, otl: usize) -> LeafBasis {
    let b = old.basis[otl];
    let r = b.rank as usize;
    if r == 0 {
        return LeafBasis::default();
    }
    let skel =
        old.skel[old.skel_off[otl] as usize..old.skel_off[otl + 1] as usize].to_vec();
    let rn = old.tgt_leaves[otl].len();
    let off = b.p.off as usize;
    let p: Vec<f32> = if b.p.bf16 {
        old.bf16a[off..off + rn * r].iter().map(|&v| bf16_decode(v)).collect()
    } else {
        old.f32a[off..off + rn * r].to_vec()
    };
    LeafBasis { skel, rank: r, p }
}

impl H2Field {
    /// Incremental counterpart of [`H2Field::build`]: lift the cluster
    /// basis of every cut leaf whose subtree is clean and whose far
    /// sample maps pointwise onto its old counterpart (same physical
    /// coordinates on both sides ⇒ the from-scratch basis would be
    /// bit-equal), recompute the rest, then run the shared build body.
    /// Bit-identical to a fresh build over `part` at any thread count —
    /// transfers and couplings are always recomputed, but they are pure
    /// functions of the (identical) skeletons.
    #[allow(clippy::too_many_arguments)]
    pub fn update(
        old: &H2Field,
        part_old: &Partition,
        part: &Partition,
        coords: &[f32],
        d: usize,
        inv_h2: f32,
        tol: f32,
        precision: Precision,
        delta: &SideDelta,
        threads: usize,
    ) -> H2Field {
        obs::span!("hmat.update");
        assert_eq!(coords.len(), part.n * d);
        assert_eq!(
            old.tgt_leaves.len() + 1,
            old.skel_off.len(),
            "old H2 field does not match its own cut"
        );
        let pool = ThreadPool::new_or_default(threads);
        let nleaf = part.leaves.len();

        // A lifted basis is only valid when it was built for the same
        // tolerance and precision regime.
        if old.tol != tol || old.precision != precision {
            let plan: Vec<Option<LeafBasis>> = vec![None; nleaf];
            counters::add(Counter::UpdateH2LeavesRefactored, nleaf as u64);
            return Self::build_impl(part, coords, d, inv_h2, tol, precision, &pool, &plan);
        }

        let old_ord = cut_ordinals(part_old);
        let samples_new = leaf_samples(part);
        let samples_old = leaf_samples(part_old);

        // Clean-leaf correspondence: new cut-leaf ordinal → old ordinal
        // with an unchanged subtree population.
        let leaf_old: Vec<Option<u32>> = (0..nleaf)
            .map(|l| {
                let tn = part.cut[l] as usize;
                if !delta.clean[tn] {
                    return None;
                }
                let otl = *old_ord.get(&delta.node_map[tn])?;
                (part_old.leaves[otl as usize].len() == part.leaves[l].len()).then_some(otl)
            })
            .collect();

        let plan: Vec<Option<LeafBasis>> = (0..nleaf)
            .map(|l| {
                let otl = leaf_old[l]? as usize;
                let sn = &samples_new[l];
                let so = &samples_old[otl];
                if sn.len() != so.len() {
                    return None;
                }
                // Every sampled far index must land in a clean leaf at
                // the matching old offset — then both samples address the
                // same physical coordinates and the basis is bit-equal.
                for (&jn, &jo) in sn.iter().zip(so) {
                    let sl = part.leaves.partition_point(|sp| sp.hi <= jn);
                    debug_assert!(sl < nleaf && part.leaves[sl].lo <= jn);
                    let osl = leaf_old[sl]? as usize;
                    if jo != part_old.leaves[osl].lo + (jn - part.leaves[sl].lo) {
                        return None;
                    }
                }
                Some(lift_basis(old, otl))
            })
            .collect();

        let reused = plan.iter().filter(|p| p.is_some()).count();
        counters::add(Counter::UpdateH2LeavesReused, reused as u64);
        counters::add(Counter::UpdateH2LeavesRefactored, (nleaf - reused) as u64);

        Self::build_impl(part, coords, d, inv_h2, tol, precision, &pool, &plan)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;
    use crate::hmat::admissible::partition;
    use crate::hmat::apply::worker_scratch;
    use crate::hmat::store::FarField;
    use crate::tree::boxtree::BoxTree;
    use crate::util::rng::Rng;

    fn setup(n: usize, tol: f32, precision: Precision) -> (Vec<f32>, Partition, H2Field) {
        let ds = SynthSpec::blobs(n, 3, 4, 13).generate();
        let tree = BoxTree::build(&ds, 8, 24);
        let coords = ds.permuted(&tree.perm).raw().to_vec();
        let part = partition(&tree, 32, 1.0);
        let far = H2Field::build(&part, &coords, 3, 0.6, tol, precision, 2);
        (coords, part, far)
    }

    /// f64 oracle of the far field alone (same as the ACA apply tests).
    fn far_oracle(coords: &[f32], part: &Partition, x: &[f32]) -> Vec<f64> {
        let gen = GaussGen {
            coords,
            d: 3,
            inv_h2: 0.6,
        };
        let mut y = vec![0.0f64; part.n];
        for fb in &part.far {
            for i in fb.rows.lo..fb.rows.hi {
                let mut acc = 0.0f64;
                for j in fb.cols.lo..fb.cols.hi {
                    acc += gen.entry_f64(i as usize, j as usize) * x[j as usize] as f64;
                }
                y[i as usize] += acc;
            }
        }
        y
    }

    fn rel_err(got: &[f32], want: &[f64]) -> (f64, f64) {
        let norm: f64 = want.iter().map(|w| w * w).sum::<f64>().sqrt();
        let err: f64 = got
            .iter()
            .zip(want)
            .map(|(&g, &w)| (g as f64 - w) * (g as f64 - w))
            .sum::<f64>()
            .sqrt();
        (err, norm)
    }

    #[test]
    fn bf16_roundtrip_is_idempotent_and_bounded() {
        assert_eq!(bf16_decode(bf16_encode(0.0)), 0.0);
        assert_eq!(bf16_decode(bf16_encode(1.0)), 1.0);
        assert_eq!(bf16_decode(bf16_encode(-2.5)), -2.5);
        let mut rng = Rng::new(3);
        for _ in 0..1000 {
            let v = (rng.f32() - 0.5) * 8.0;
            let q = bf16_decode(bf16_encode(v));
            // Idempotent (update lift depends on this) and within the
            // 8-bit-mantissa RNE half-ULP bound.
            assert_eq!(bf16_encode(q), bf16_encode(v));
            assert!((v - q).abs() <= v.abs() / 256.0 + f32::MIN_POSITIVE);
        }
    }

    #[test]
    fn sample_indices_merges_and_caps() {
        let mut spans = vec![
            Span { lo: 10, hi: 20 },
            Span { lo: 0, hi: 12 },
            Span { lo: 40, hi: 44 },
        ];
        let s = sample_indices(&mut spans, 1000);
        // Overlap [0,12)∪[10,20) merges; stride 1 keeps everything.
        let want: Vec<u32> = (0..20).chain(40..44).collect();
        assert_eq!(s, want);
        let mut spans2 = vec![Span { lo: 0, hi: 100 }];
        let s2 = sample_indices(&mut spans2, 10);
        assert!(s2.len() <= 10 && s2[0] == 0);
        // Deterministic: same input, same output.
        let mut spans3 = vec![Span { lo: 0, hi: 100 }];
        assert_eq!(sample_indices(&mut spans3, 10), s2);
    }

    #[test]
    fn leaf_basis_interpolates_its_far_sample() {
        let tol = 1e-3f32;
        let ds = SynthSpec::blobs(600, 3, 4, 13).generate();
        let tree = BoxTree::build(&ds, 8, 24);
        let coords = ds.permuted(&tree.perm).raw().to_vec();
        let part = partition(&tree, 32, 1.0);
        let gen = GaussGen {
            coords: &coords,
            d: 3,
            inv_h2: 0.6,
        };
        let samples = leaf_samples(&part);
        let mut checked = 0;
        for (l, sp) in part.leaves.iter().enumerate() {
            if samples[l].is_empty() {
                continue;
            }
            let b = leaf_basis(&gen, *sp, &samples[l], tol);
            if b.rank == 0 || b.rank == sp.len() {
                continue; // zero block or identity fallback: exact by construction
            }
            // ‖A − P·A[I,:]‖_F ≤ O(tol)·‖A‖_F over the far sample.
            let rn = sp.len();
            let cn = samples[l].len();
            let (mut err2, mut n2) = (0.0f64, 0.0f64);
            for i in 0..rn {
                for j in 0..cn {
                    let a = gen.entry_f64(sp.lo as usize + i, samples[l][j] as usize);
                    let mut p = 0.0f64;
                    for (k, &sk) in b.skel.iter().enumerate() {
                        p += b.p[i * b.rank + k] as f64
                            * gen.entry_f64(sp.lo as usize + sk as usize, samples[l][j] as usize);
                    }
                    err2 += (a - p) * (a - p);
                    n2 += a * a;
                }
            }
            assert!(
                err2.sqrt() <= 20.0 * tol as f64 * n2.sqrt() + 1e-12,
                "leaf {l}: interpolation err {} vs norm {}",
                err2.sqrt(),
                n2.sqrt()
            );
            checked += 1;
        }
        assert!(checked > 0, "no compressed leaf bases exercised");
    }

    #[test]
    fn h2_apply_matches_f64_oracle() {
        let tol = 1e-3f32;
        let (coords, part, far) = setup(700, tol, Precision::F32);
        assert!(!far.is_empty(), "test needs far blocks");
        let mut rng = Rng::new(7);
        let x: Vec<f32> = (0..700).map(|_| rng.f32() - 0.5).collect();
        let want = far_oracle(&coords, &part, &x);
        let pool = ThreadPool::new(2);
        let scratch = worker_scratch(pool.threads);
        let mut y = vec![0.0f32; 700];
        far.apply_acc(&x, 1, &mut y, &pool, Dispatch::Scalar, &scratch);
        let (err, norm) = rel_err(&y, &want);
        assert!(
            err <= 10.0 * tol as f64 * norm + 1e-12,
            "h2 apply err {err} vs norm {norm} ({})",
            far.describe()
        );
    }

    #[test]
    fn h2_apply_bf16_matches_oracle_and_shrinks_storage() {
        let tol = 2e-2f32;
        let (coords, part, far) = setup(700, tol, Precision::Bf16);
        let (_, _, far32) = setup(700, tol, Precision::F32);
        assert!(far.bf16_factors() > 0, "tol admits bf16, none selected");
        assert!(
            far.far_bytes() < far32.far_bytes(),
            "bf16 build must shrink storage: {} vs {}",
            far.far_bytes(),
            far32.far_bytes()
        );
        let mut rng = Rng::new(7);
        let x: Vec<f32> = (0..700).map(|_| rng.f32() - 0.5).collect();
        let want = far_oracle(&coords, &part, &x);
        let pool = ThreadPool::new(2);
        let scratch = worker_scratch(pool.threads);
        let mut y = vec![0.0f32; 700];
        far.apply_acc(&x, 1, &mut y, &pool, Dispatch::Scalar, &scratch);
        let (err, norm) = rel_err(&y, &want);
        assert!(
            err <= 10.0 * tol as f64 * norm + 1e-12,
            "bf16 h2 apply err {err} vs norm {norm} ({})",
            far.describe()
        );
    }

    #[test]
    fn h2_apply_accumulates_and_is_thread_invariant() {
        let (_, _, far) = setup(600, 1e-3, Precision::F32);
        let mut rng = Rng::new(11);
        let x: Vec<f32> = (0..600).map(|_| rng.f32()).collect();
        let base: Vec<f32> = (0..600).map(|_| rng.f32()).collect();
        let mut reference: Vec<f32> = Vec::new();
        for threads in [1usize, 2, 8] {
            let pool = ThreadPool::new(threads);
            let scratch = worker_scratch(pool.threads);
            let mut y = base.clone();
            far.apply_acc(&x, 1, &mut y, &pool, Dispatch::Scalar, &scratch);
            assert!(y.iter().zip(&base).any(|(a, b)| a != b), "apply was a no-op");
            if reference.is_empty() {
                reference = y;
            } else {
                assert!(
                    y.iter().zip(&reference).all(|(a, b)| a.to_bits() == b.to_bits()),
                    "thread-count bit-identity violated at threads={threads}"
                );
            }
        }
    }

    #[test]
    fn multi_rhs_columns_bitexact_with_single_rhs() {
        let (_, _, far) = setup(500, 1e-3, Precision::F32);
        let n = 500;
        let mut rng = Rng::new(23);
        let k = 5;
        let x: Vec<f32> = (0..n * k).map(|_| rng.f32() - 0.5).collect();
        let pool = ThreadPool::new(2);
        let scratch = worker_scratch(pool.threads);
        let mut y = vec![0.0f32; n * k];
        far.apply_acc(&x, k, &mut y, &pool, Dispatch::Scalar, &scratch);
        for j in 0..k {
            let xj: Vec<f32> = (0..n).map(|i| x[i * k + j]).collect();
            let mut yj = vec![0.0f32; n];
            far.apply_acc(&xj, 1, &mut yj, &pool, Dispatch::Scalar, &scratch);
            for i in 0..n {
                assert_eq!(
                    y[i * k + j].to_bits(),
                    yj[i].to_bits(),
                    "col {j} row {i} differs from k=1"
                );
            }
        }
    }

    #[test]
    fn build_bitidentical_across_build_threads() {
        for precision in [Precision::F32, Precision::Bf16] {
            let ds = SynthSpec::blobs(800, 3, 4, 13).generate();
            let tree = BoxTree::build(&ds, 8, 24);
            let coords = ds.permuted(&tree.perm).raw().to_vec();
            let part = partition(&tree, 32, 1.0);
            let reference = H2Field::build(&part, &coords, 3, 0.6, 1e-3, precision, 1);
            for threads in [2usize, 8] {
                let got = H2Field::build(&part, &coords, 3, 0.6, 1e-3, precision, threads);
                assert!(
                    reference.bits_eq(&got),
                    "build differs at threads={threads} precision={precision:?}"
                );
            }
        }
    }

    #[test]
    fn h2_storage_beats_aca_at_matching_tol() {
        let tol = 1e-3f32;
        let ds = SynthSpec::blobs(1500, 3, 4, 13).generate();
        let tree = BoxTree::build(&ds, 8, 24);
        let coords = ds.permuted(&tree.perm).raw().to_vec();
        let part = partition(&tree, 64, 1.0);
        let aca = FarField::build(&part, &coords, 3, 0.6, tol, 2);
        let h2 = H2Field::build(&part, &coords, 3, 0.6, tol, Precision::F32, 2);
        assert_eq!(h2.coverage(), aca.coverage(), "same partition, same cells");
        assert!(
            h2.far_bytes() < aca.far_bytes(),
            "h2 bytes {} must undercut aca bytes {} ({} / {})",
            h2.far_bytes(),
            aca.far_bytes(),
            h2.describe(),
            aca.describe()
        );
        assert!(
            (h2.far_bytes() as f64) < 0.3 * h2.dense_far_bytes() as f64,
            "h2 bytes {} vs dense {}",
            h2.far_bytes(),
            h2.dense_far_bytes()
        );
    }

    #[test]
    fn empty_far_field_is_a_noop() {
        let ds = SynthSpec::blobs(200, 2, 3, 3).generate();
        let tree = BoxTree::build(&ds, 8, 24);
        let part = partition(&tree, 32, 1.0);
        let far = H2Field::build(
            &part,
            ds.permuted(&tree.perm).raw(),
            2,
            0.6,
            1e-3,
            Precision::F32,
            2,
        );
        if !far.is_empty() {
            return; // partition produced far blocks at this size: nothing to check
        }
        let pool = ThreadPool::new(2);
        let scratch = worker_scratch(pool.threads);
        let x = vec![1.0f32; 200];
        let mut y = vec![2.5f32; 200];
        far.apply_acc(&x, 1, &mut y, &pool, Dispatch::Scalar, &scratch);
        assert!(y.iter().all(|&v| v == 2.5));
    }
}
