//! The far-field representation seam.
//!
//! [`FullKernelEngine`](crate::hmat::FullKernelEngine) and every consumer
//! above it (epoch patching, KRR, the CLI paths, benches) talk to the far
//! field exclusively through [`FarFieldRepr`] and the concrete-but-opaque
//! [`FarFieldStore`] — never to [`FarField`] or [`H2Field`] directly.
//! The contract every representation must honor:
//!
//! * `apply_acc` **accumulates** `y += far·x` (the near apply overwrites
//!   first) through the dispatched `csb::kernel` GEMMs, bit-identically
//!   across thread counts;
//! * construction is a pure function of `(partition, coords, tol, …)` at
//!   any build thread count, so incremental updates can be cross-checked
//!   against from-scratch builds bit-for-bit;
//! * byte accounting (`far_bytes`, `dense_far_bytes`) uses factor arenas
//!   only — packed panel mirrors are excluded on both sides, keeping the
//!   ACA-vs-H² storage comparison honest.

use crate::csb::kernel::Dispatch;
use crate::csb::panel::AlignedF32;
use crate::hmat::h2::H2Field;
use crate::hmat::store::FarField;
use crate::hmat::FarFieldMode;
use crate::par::pool::ThreadPool;
use std::sync::Mutex;

/// What the engine (and everything above it) needs from a far field.
pub trait FarFieldRepr {
    /// `y += far · x` with `k` RHS columns; see the module contract.
    fn apply_acc(
        &self,
        x: &[f32],
        k: usize,
        y: &mut [f32],
        pool: &ThreadPool,
        dispatch: Dispatch,
        scratch: &[Mutex<AlignedF32>],
    );
    /// No far blocks at all (`--far off` or a partition with no
    /// admissible pairs).
    fn is_empty(&self) -> bool;
    /// Total far-field cells covered (near + far must tile `n²`).
    fn coverage(&self) -> u64;
    /// Factor arena bytes (panels excluded).
    fn far_bytes(&self) -> u64;
    /// Bytes a dense f32 materialization of the far blocks would need.
    fn dense_far_bytes(&self) -> u64;
    /// Number of far blocks.
    fn block_count(&self) -> usize;
    fn eta(&self) -> f32;
    fn tol(&self) -> f32;
    fn mode(&self) -> FarFieldMode;
    /// One stats line for logs/benches.
    fn describe(&self) -> String;
}

impl FarFieldRepr for FarField {
    fn apply_acc(
        &self,
        x: &[f32],
        k: usize,
        y: &mut [f32],
        pool: &ThreadPool,
        dispatch: Dispatch,
        scratch: &[Mutex<AlignedF32>],
    ) {
        FarField::apply_acc(self, x, k, y, pool, dispatch, scratch)
    }

    fn is_empty(&self) -> bool {
        FarField::is_empty(self)
    }

    fn coverage(&self) -> u64 {
        FarField::coverage(self)
    }

    fn far_bytes(&self) -> u64 {
        FarField::far_bytes(self)
    }

    fn dense_far_bytes(&self) -> u64 {
        FarField::dense_far_bytes(self)
    }

    fn block_count(&self) -> usize {
        self.blocks.len()
    }

    fn eta(&self) -> f32 {
        self.eta
    }

    fn tol(&self) -> f32 {
        self.tol
    }

    fn mode(&self) -> FarFieldMode {
        FarFieldMode::Aca
    }

    fn describe(&self) -> String {
        FarField::describe(self)
    }
}

impl FarFieldRepr for H2Field {
    fn apply_acc(
        &self,
        x: &[f32],
        k: usize,
        y: &mut [f32],
        pool: &ThreadPool,
        dispatch: Dispatch,
        scratch: &[Mutex<AlignedF32>],
    ) {
        H2Field::apply_acc(self, x, k, y, pool, dispatch, scratch)
    }

    fn is_empty(&self) -> bool {
        H2Field::is_empty(self)
    }

    fn coverage(&self) -> u64 {
        H2Field::coverage(self)
    }

    fn far_bytes(&self) -> u64 {
        H2Field::far_bytes(self)
    }

    fn dense_far_bytes(&self) -> u64 {
        H2Field::dense_far_bytes(self)
    }

    fn block_count(&self) -> usize {
        H2Field::block_count(self)
    }

    fn eta(&self) -> f32 {
        self.eta
    }

    fn tol(&self) -> f32 {
        self.tol
    }

    fn mode(&self) -> FarFieldMode {
        H2Field::mode(self)
    }

    fn describe(&self) -> String {
        H2Field::describe(self)
    }
}

/// The engine's owned far field: one of the two representations.  An
/// engine built with `--far off` stores an empty ACA field (zero blocks,
/// zero bytes) so every consumer sees one uniform surface.
#[derive(Clone)]
pub enum FarFieldStore {
    Aca(FarField),
    H2(H2Field),
}

impl FarFieldStore {
    pub fn as_aca(&self) -> Option<&FarField> {
        match self {
            FarFieldStore::Aca(f) => Some(f),
            FarFieldStore::H2(_) => None,
        }
    }

    pub fn as_h2(&self) -> Option<&H2Field> {
        match self {
            FarFieldStore::H2(f) => Some(f),
            FarFieldStore::Aca(_) => None,
        }
    }

    /// Same representation, same structure, bitwise-equal factors — the
    /// cross-check the incremental-update tests assert.
    pub fn bits_eq(&self, other: &FarFieldStore) -> bool {
        match (self, other) {
            (FarFieldStore::Aca(a), FarFieldStore::Aca(b)) => a.bits_eq(b),
            (FarFieldStore::H2(a), FarFieldStore::H2(b)) => a.bits_eq(b),
            _ => false,
        }
    }
}

impl FarFieldRepr for FarFieldStore {
    fn apply_acc(
        &self,
        x: &[f32],
        k: usize,
        y: &mut [f32],
        pool: &ThreadPool,
        dispatch: Dispatch,
        scratch: &[Mutex<AlignedF32>],
    ) {
        match self {
            FarFieldStore::Aca(f) => f.apply_acc(x, k, y, pool, dispatch, scratch),
            FarFieldStore::H2(f) => f.apply_acc(x, k, y, pool, dispatch, scratch),
        }
    }

    fn is_empty(&self) -> bool {
        match self {
            FarFieldStore::Aca(f) => FarFieldRepr::is_empty(f),
            FarFieldStore::H2(f) => FarFieldRepr::is_empty(f),
        }
    }

    fn coverage(&self) -> u64 {
        match self {
            FarFieldStore::Aca(f) => FarFieldRepr::coverage(f),
            FarFieldStore::H2(f) => FarFieldRepr::coverage(f),
        }
    }

    fn far_bytes(&self) -> u64 {
        match self {
            FarFieldStore::Aca(f) => FarFieldRepr::far_bytes(f),
            FarFieldStore::H2(f) => FarFieldRepr::far_bytes(f),
        }
    }

    fn dense_far_bytes(&self) -> u64 {
        match self {
            FarFieldStore::Aca(f) => FarFieldRepr::dense_far_bytes(f),
            FarFieldStore::H2(f) => FarFieldRepr::dense_far_bytes(f),
        }
    }

    fn block_count(&self) -> usize {
        match self {
            FarFieldStore::Aca(f) => FarFieldRepr::block_count(f),
            FarFieldStore::H2(f) => FarFieldRepr::block_count(f),
        }
    }

    fn eta(&self) -> f32 {
        match self {
            FarFieldStore::Aca(f) => f.eta,
            FarFieldStore::H2(f) => f.eta,
        }
    }

    fn tol(&self) -> f32 {
        match self {
            FarFieldStore::Aca(f) => f.tol,
            FarFieldStore::H2(f) => f.tol,
        }
    }

    fn mode(&self) -> FarFieldMode {
        match self {
            FarFieldStore::Aca(f) => FarFieldRepr::mode(f),
            FarFieldStore::H2(f) => FarFieldRepr::mode(f),
        }
    }

    fn describe(&self) -> String {
        match self {
            FarFieldStore::Aca(f) => FarFieldRepr::describe(f),
            FarFieldStore::H2(f) => FarFieldRepr::describe(f),
        }
    }
}
