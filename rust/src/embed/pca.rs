//! PCA by blocked subspace (orthogonal) iteration — the paper's
//! "economic-sparse SVD": only the top `d` principal axes are computed,
//! never the full spectrum (§2.4 "without requiring the computation of all
//! D singular values").
//!
//! For n points in R^D we iterate `V <- orth(Cov · V)` with the covariance
//! product computed as `Xᵀ(X V)/n` in two blocked passes (no D×D covariance
//! is materialized for large D).  Convergence is measured on the subspace
//! angle via the Rayleigh quotient deltas.

use crate::data::dataset::Dataset;
use crate::obs::{self, counters, Counter};
use crate::par::pool::ThreadPool;
use crate::util::rng::Rng;

/// Result of a truncated PCA.
#[derive(Clone, Debug)]
pub struct Pca {
    /// Embedding dimension.
    pub d: usize,
    /// Ambient dimension.
    pub ambient: usize,
    /// Principal axes, row-major `d x ambient` (each row a unit axis).
    pub axes: Vec<f64>,
    /// Eigenvalues of the covariance (variance along each axis), desc.
    pub eigenvalues: Vec<f64>,
    /// Total variance (trace of covariance), for explained-variance ratios.
    pub total_variance: f64,
    /// Data mean subtracted before projection.
    pub mean: Vec<f32>,
}

impl Pca {
    /// Fraction of total variance captured by the first `k <= d` axes —
    /// the paper's distortion-tolerance ratio Σσᵢ²/‖X‖_F².
    pub fn explained(&self, k: usize) -> f64 {
        let s: f64 = self.eigenvalues[..k.min(self.eigenvalues.len())].iter().sum();
        if self.total_variance > 0.0 {
            s / self.total_variance
        } else {
            0.0
        }
    }

    /// Project the dataset onto the top `k <= d` axes.
    pub fn project(&self, ds: &Dataset, k: usize) -> Dataset {
        assert!(k <= self.d);
        assert_eq!(ds.d(), self.ambient);
        let mut out = vec![0.0f32; ds.n() * k];
        for i in 0..ds.n() {
            let row = ds.row(i);
            for a in 0..k {
                let axis = &self.axes[a * self.ambient..(a + 1) * self.ambient];
                let mut s = 0.0f64;
                for j in 0..self.ambient {
                    s += (row[j] - self.mean[j]) as f64 * axis[j];
                }
                out[i * k + a] = s as f32;
            }
        }
        let mut e = Dataset::new(ds.n(), k, out);
        e.labels = ds.labels.clone();
        e
    }
}

/// Fixed row-chunk size of the parallel Gram/variance accumulations.  The
/// chunking is **independent of the thread count** and the per-chunk
/// partials are reduced in chunk order, so `pca_par` is bit-identical
/// across `threads` values (f64 addition is not associative; thread-count-
/// dependent chunk boundaries would regroup the sums).  256 rows keeps a
/// chunk ~10⁵ flops at SIFT-like dimensions — large enough to amortize the
/// claim, small enough to balance the pool.
const PCA_CHUNK: usize = 256;

/// Fixed chunking of `0..n` (see [`PCA_CHUNK`]).
fn fixed_ranges(n: usize) -> Vec<(usize, usize)> {
    (0..n)
        .step_by(PCA_CHUNK)
        .map(|lo| (lo, (lo + PCA_CHUNK).min(n)))
        .collect()
}

/// Compute the top-`d` principal axes of `ds` with the machine-default
/// worker count (see [`pca_par`]).
pub fn pca(ds: &Dataset, d: usize, iters: usize, seed: u64) -> Pca {
    pca_par(ds, d, iters, seed, 0)
}

/// Compute the top-`d` principal axes of `ds`.
///
/// `iters` subspace iterations (8–12 suffice for the well-separated spectra
/// the reordering cares about); deterministic for a given `seed`, and
/// bit-identical across `threads` values (0 = machine default,
/// `NNI_THREADS`-respecting): partial Gram/variance sums are accumulated
/// over fixed-size row chunks and reduced in chunk order.
pub fn pca_par(ds: &Dataset, d: usize, iters: usize, seed: u64, threads: usize) -> Pca {
    obs::span!("embed.pca");
    counters::add(Counter::PcaRuns, 1);
    let n = ds.n();
    let dim = ds.d();
    let d = d.min(dim);
    let mean = ds.mean();
    let pool = ThreadPool::new_or_default(threads);
    let ranges = fixed_ranges(n);

    // Total variance = (1/n) sum_i |x_i - mean|^2, chunk partials reduced
    // in fixed order.
    let partial_var: Vec<f64> = pool.map(&ranges, |&(lo, hi)| {
        let mut acc = 0.0f64;
        for i in lo..hi {
            for (k, &v) in ds.row(i).iter().enumerate() {
                let t = (v - mean[k]) as f64;
                acc += t * t;
            }
        }
        acc
    });
    let mut total: f64 = partial_var.iter().sum();
    total /= n as f64;

    // V: dim x d column block, initialized randomly.
    let mut rng = Rng::new(seed);
    let mut v = vec![0.0f64; dim * d];
    for x in v.iter_mut() {
        *x = rng.normal();
    }
    orthonormalize(&mut v, dim, d);

    let mut eigs = vec![0.0f64; d];
    for _ in 0..iters.max(1) {
        // W = Cov · V = Xcᵀ (Xc V) / n, blocked over points, parallel
        // over fixed-size row chunks with per-chunk accumulators.
        let partials: Vec<Vec<f64>> = pool.map(&ranges, |&(lo, hi)| {
            let mut w = vec![0.0f64; dim * d];
            let mut proj = vec![0.0f64; d];
            for i in lo..hi {
                let row = ds.row(i);
                for p in proj.iter_mut() {
                    *p = 0.0;
                }
                for j in 0..dim {
                    let xj = (row[j] - mean[j]) as f64;
                    if xj != 0.0 {
                        let vr = &v[j * d..(j + 1) * d];
                        for a in 0..d {
                            proj[a] += xj * vr[a];
                        }
                    }
                }
                for j in 0..dim {
                    let xj = (row[j] - mean[j]) as f64;
                    if xj != 0.0 {
                        let wr = &mut w[j * d..(j + 1) * d];
                        for a in 0..d {
                            wr[a] += xj * proj[a];
                        }
                    }
                }
            }
            w
        });
        let mut w = vec![0.0f64; dim * d];
        for p in &partials {
            for (wi, pi) in w.iter_mut().zip(p) {
                *wi += pi;
            }
        }
        for x in w.iter_mut() {
            *x /= n as f64;
        }
        // Rayleigh quotients BEFORE orthonormalization: eig_a ≈ |w_a| since
        // v_a is unit: lambda_a = v_aᵀ Cov v_a = v_a · w_a.
        for (a, e) in eigs.iter_mut().enumerate() {
            let mut s = 0.0;
            for j in 0..dim {
                s += v[j * d + a] * w[j * d + a];
            }
            *e = s;
        }
        v = w;
        orthonormalize(&mut v, dim, d);
    }

    // Sort axes by eigenvalue descending (subspace iteration usually
    // delivers them ordered, but enforce it).  `total_cmp` so a degenerate
    // NaN eigenvalue cannot panic the sort.
    let mut idx: Vec<usize> = (0..d).collect();
    idx.sort_by(|&a, &b| eigs[b].total_cmp(&eigs[a]));
    let mut axes = vec![0.0f64; d * dim];
    let mut eigenvalues = vec![0.0f64; d];
    for (out_a, &src_a) in idx.iter().enumerate() {
        eigenvalues[out_a] = eigs[src_a];
        for j in 0..dim {
            axes[out_a * dim + j] = v[j * d + src_a];
        }
    }

    Pca {
        d,
        ambient: dim,
        axes,
        eigenvalues,
        total_variance: total,
        mean,
    }
}

/// Gram–Schmidt on the columns of the `dim x d` block `v`.
fn orthonormalize(v: &mut [f64], dim: usize, d: usize) {
    for a in 0..d {
        for b in 0..a {
            let mut dot = 0.0;
            for j in 0..dim {
                dot += v[j * d + a] * v[j * d + b];
            }
            for j in 0..dim {
                v[j * d + a] -= dot * v[j * d + b];
            }
        }
        let mut norm = 0.0;
        for j in 0..dim {
            norm += v[j * d + a] * v[j * d + a];
        }
        let norm = norm.sqrt().max(1e-300);
        for j in 0..dim {
            v[j * d + a] /= norm;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Data with a known dominant direction: x = t*u + small noise.
    fn line_data(n: usize, dim: usize, seed: u64) -> (Dataset, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let mut u: Vec<f64> = (0..dim).map(|_| rng.normal()).collect();
        let nu: f64 = u.iter().map(|x| x * x).sum::<f64>().sqrt();
        for x in u.iter_mut() {
            *x /= nu;
        }
        let mut xs = vec![0.0f32; n * dim];
        for i in 0..n {
            let t = 3.0 * rng.normal();
            for j in 0..dim {
                xs[i * dim + j] = (t * u[j] + 0.01 * rng.normal()) as f32;
            }
        }
        (Dataset::new(n, dim, xs), u)
    }

    #[test]
    fn recovers_dominant_axis() {
        let (ds, u) = line_data(500, 20, 1);
        let p = pca(&ds, 2, 12, 7);
        let axis = &p.axes[..20];
        let dot: f64 = axis.iter().zip(&u).map(|(a, b)| a * b).sum();
        assert!(dot.abs() > 0.99, "axis alignment {dot}");
        assert!(p.eigenvalues[0] > 5.0 * p.eigenvalues[1]);
    }

    #[test]
    fn explained_variance_close_to_one_for_line() {
        let (ds, _) = line_data(400, 10, 2);
        let p = pca(&ds, 1, 12, 3);
        assert!(p.explained(1) > 0.95, "explained {}", p.explained(1));
    }

    #[test]
    fn axes_are_orthonormal() {
        let ds = crate::data::synth::SynthSpec::sift_like(400, 5).generate();
        let p = pca(&ds, 3, 10, 1);
        for a in 0..3 {
            for b in 0..=a {
                let dot: f64 = (0..p.ambient)
                    .map(|j| p.axes[a * p.ambient + j] * p.axes[b * p.ambient + j])
                    .sum();
                if a == b {
                    assert!((dot - 1.0).abs() < 1e-8);
                } else {
                    assert!(dot.abs() < 1e-6);
                }
            }
        }
    }

    #[test]
    fn projection_shape_and_centering() {
        let ds = crate::data::synth::SynthSpec::sift_like(300, 6).generate();
        let p = pca(&ds, 3, 8, 2);
        let e = p.project(&ds, 2);
        assert_eq!(e.n(), 300);
        assert_eq!(e.d(), 2);
        // projected data is centered
        for m in e.mean() {
            assert!(m.abs() < 1e-3, "mean {m}");
        }
    }

    #[test]
    fn pca_bitidentical_across_threads() {
        // Fixed-chunk Gram accumulation: the result must not depend on the
        // worker count.
        let ds = crate::data::synth::SynthSpec::sift_like(700, 9).generate();
        let reference = pca_par(&ds, 3, 8, 5, 1);
        for threads in [2usize, 8] {
            let p = pca_par(&ds, 3, 8, 5, threads);
            assert_eq!(
                p.total_variance.to_bits(),
                reference.total_variance.to_bits(),
                "threads={threads}"
            );
            assert!(
                p.axes
                    .iter()
                    .zip(&reference.axes)
                    .all(|(a, b)| a.to_bits() == b.to_bits()),
                "axes differ at threads={threads}"
            );
            assert!(p
                .eigenvalues
                .iter()
                .zip(&reference.eigenvalues)
                .all(|(a, b)| a.to_bits() == b.to_bits()));
        }
    }

    #[test]
    fn eigenvalues_descend() {
        let ds = crate::data::synth::SynthSpec::sift_like(500, 8).generate();
        let p = pca(&ds, 4, 10, 4);
        for w in p.eigenvalues.windows(2) {
            assert!(w[0] >= w[1] - 1e-9);
        }
    }
}
