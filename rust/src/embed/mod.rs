//! Low-dimensional embedding: data-specific principal feature axes (§2.4).

pub mod pca;
