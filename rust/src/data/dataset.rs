//! Dense, row-major point sets with binary I/O.
//!
//! The canonical container for source/target data everywhere in the crate:
//! `n` points in `R^d`, stored as one contiguous `Vec<f32>` (row-major) so
//! that a tree-ordered permutation makes cluster segments physically
//! contiguous — the paper's prerequisite for charge/potential locality.

use std::io::{Read, Write};
use std::path::Path;

/// `n` points in `R^d`, row-major.
#[derive(Clone, Debug, PartialEq)]
pub struct Dataset {
    n: usize,
    d: usize,
    xs: Vec<f32>,
    /// Optional class labels (synthetic data records ground truth here).
    pub labels: Option<Vec<u32>>,
}

impl Dataset {
    pub fn new(n: usize, d: usize, xs: Vec<f32>) -> Self {
        assert_eq!(xs.len(), n * d, "data length must be n*d");
        Dataset {
            n,
            d,
            xs,
            labels: None,
        }
    }

    pub fn zeros(n: usize, d: usize) -> Self {
        Dataset::new(n, d, vec![0.0; n * d])
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn d(&self) -> usize {
        self.d
    }

    /// Row `i` as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.xs[i * self.d..(i + 1) * self.d]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.xs[i * self.d..(i + 1) * self.d]
    }

    /// Raw storage.
    #[inline]
    pub fn raw(&self) -> &[f32] {
        &self.xs
    }

    #[inline]
    pub fn raw_mut(&mut self) -> &mut [f32] {
        &mut self.xs
    }

    /// Squared Euclidean distance between rows `i` and `j`.
    #[inline]
    pub fn sqdist(&self, i: usize, j: usize) -> f32 {
        let (a, b) = (self.row(i), self.row(j));
        let mut s = 0.0f32;
        for k in 0..self.d {
            let t = a[k] - b[k];
            s += t * t;
        }
        s
    }

    /// Apply a permutation: output row `k` = input row `perm[k]`.
    /// Labels are carried along.
    pub fn permuted(&self, perm: &[usize]) -> Dataset {
        assert_eq!(perm.len(), self.n);
        let mut xs = Vec::with_capacity(self.xs.len());
        for &p in perm {
            xs.extend_from_slice(self.row(p));
        }
        let labels = self
            .labels
            .as_ref()
            .map(|l| perm.iter().map(|&p| l[p]).collect());
        Dataset {
            n: self.n,
            d: self.d,
            xs,
            labels,
        }
    }

    /// Per-coordinate mean.
    pub fn mean(&self) -> Vec<f32> {
        let mut m = vec![0.0f64; self.d];
        for i in 0..self.n {
            for (k, &v) in self.row(i).iter().enumerate() {
                m[k] += v as f64;
            }
        }
        m.iter().map(|&s| (s / self.n as f64) as f32).collect()
    }

    /// Center in place (subtract mean); returns the mean.
    pub fn center(&mut self) -> Vec<f32> {
        let m = self.mean();
        for i in 0..self.n {
            let r = self.row_mut(i);
            for (k, mv) in m.iter().enumerate() {
                r[k] -= mv;
            }
        }
        m
    }

    /// Keep only the rows with the given indices (any count, any order).
    pub fn select(&self, idx: &[usize]) -> Dataset {
        let mut xs = Vec::with_capacity(idx.len() * self.d);
        for &p in idx {
            xs.extend_from_slice(self.row(p));
        }
        let labels = self
            .labels
            .as_ref()
            .map(|l| idx.iter().map(|&p| l[p]).collect());
        Dataset {
            n: idx.len(),
            d: self.d,
            xs,
            labels,
        }
    }

    /// Binary serialization: magic, n, d, has_labels, f32 rows, u32 labels.
    pub fn write_to<W: Write>(&self, w: &mut W) -> std::io::Result<()> {
        w.write_all(b"NNID")?;
        w.write_all(&(self.n as u64).to_le_bytes())?;
        w.write_all(&(self.d as u64).to_le_bytes())?;
        w.write_all(&[self.labels.is_some() as u8])?;
        for &x in &self.xs {
            w.write_all(&x.to_le_bytes())?;
        }
        if let Some(ls) = &self.labels {
            for &l in ls {
                w.write_all(&l.to_le_bytes())?;
            }
        }
        Ok(())
    }

    pub fn read_from<R: Read>(r: &mut R) -> std::io::Result<Dataset> {
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)?;
        if &magic != b"NNID" {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                "bad magic",
            ));
        }
        let mut b8 = [0u8; 8];
        r.read_exact(&mut b8)?;
        let n = u64::from_le_bytes(b8) as usize;
        r.read_exact(&mut b8)?;
        let d = u64::from_le_bytes(b8) as usize;
        let mut b1 = [0u8; 1];
        r.read_exact(&mut b1)?;
        let mut xs = vec![0.0f32; n * d];
        let mut b4 = [0u8; 4];
        for x in xs.iter_mut() {
            r.read_exact(&mut b4)?;
            *x = f32::from_le_bytes(b4);
        }
        let labels = if b1[0] == 1 {
            let mut ls = vec![0u32; n];
            for l in ls.iter_mut() {
                r.read_exact(&mut b4)?;
                *l = u32::from_le_bytes(b4);
            }
            Some(ls)
        } else {
            None
        };
        Ok(Dataset { n, d, xs, labels })
    }

    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        self.write_to(&mut f)
    }

    pub fn load(path: &Path) -> std::io::Result<Dataset> {
        let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
        Dataset::read_from(&mut f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_ds(n: usize, d: usize, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed);
        let xs: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let mut ds = Dataset::new(n, d, xs);
        ds.labels = Some((0..n).map(|i| (i % 7) as u32).collect());
        ds
    }

    #[test]
    fn rows_and_sqdist() {
        let ds = Dataset::new(2, 3, vec![0.0, 0.0, 0.0, 3.0, 4.0, 0.0]);
        assert_eq!(ds.row(1), &[3.0, 4.0, 0.0]);
        assert_eq!(ds.sqdist(0, 1), 25.0);
        assert_eq!(ds.sqdist(1, 1), 0.0);
    }

    #[test]
    fn permutation_roundtrip() {
        let ds = random_ds(37, 5, 1);
        let mut rng = Rng::new(2);
        let p = rng.permutation(37);
        let q = crate::order::invert(&p);
        assert_eq!(ds.permuted(&p).permuted(&q), ds);
    }

    #[test]
    fn centering_zeroes_mean() {
        let mut ds = random_ds(100, 4, 3);
        ds.center();
        for m in ds.mean() {
            assert!(m.abs() < 1e-5);
        }
    }

    #[test]
    fn io_roundtrip() {
        let ds = random_ds(23, 9, 4);
        let mut buf = Vec::new();
        ds.write_to(&mut buf).unwrap();
        let back = Dataset::read_from(&mut &buf[..]).unwrap();
        assert_eq!(back, ds);
    }

    #[test]
    fn io_rejects_bad_magic() {
        let buf = b"XXXX\0\0\0\0".to_vec();
        assert!(Dataset::read_from(&mut &buf[..]).is_err());
    }

    #[test]
    fn select_picks_rows() {
        let ds = random_ds(10, 2, 5);
        let sel = ds.select(&[3, 7]);
        assert_eq!(sel.n(), 2);
        assert_eq!(sel.row(0), ds.row(3));
        assert_eq!(sel.row(1), ds.row(7));
        assert_eq!(sel.labels.as_ref().unwrap()[1], ds.labels.as_ref().unwrap()[7]);
    }
}
