//! Datasets: dense point sets in a D-dimensional feature space, plus
//! synthetic surrogates for the paper's SIFT/GIST corpora (DESIGN.md §5).

pub mod dataset;
pub mod synth;
