//! Synthetic surrogates for the paper's SIFT (D=128) and GIST (D=960)
//! corpora (DESIGN.md §5 substitution).
//!
//! The reordering method's input signal is **multi-scale cluster structure
//! that survives projection onto the top few principal axes** — that is what
//! §2.4 exploits and what real image descriptors exhibit.  The generator
//! therefore draws points from a *hierarchical mixture of Gaussians*:
//!
//! * `branching^depth` leaf clusters arranged as clusters-of-clusters, with
//!   geometrically shrinking spread per level (multi-scale structure);
//! * cluster sizes heavy-tailed (Zipf-like) as in natural image corpora;
//! * an anisotropic ambient rotation with a decaying spectrum so that the
//!   leading PCA axes carry most inter-cluster variance (as real SIFT/GIST
//!   PCA spectra do);
//! * i.i.d. feature noise on all D dimensions (so naive coordinates are
//!   uninformative and the embedding step is genuinely exercised).

use crate::data::dataset::Dataset;
use crate::util::rng::Rng;

/// Specification of a hierarchical mixture-of-Gaussians dataset.
#[derive(Clone, Debug)]
pub struct SynthSpec {
    /// Number of points.
    pub n: usize,
    /// Ambient feature dimension (128 = SIFT-like, 960 = GIST-like).
    pub d: usize,
    /// Intrinsic dimension of the cluster-center lattice (where the
    /// multi-scale structure lives before rotation into R^d).
    pub intrinsic: usize,
    /// Hierarchy depth (levels of clusters-of-clusters).
    pub depth: usize,
    /// Children per hierarchy node.
    pub branching: usize,
    /// Spread ratio between consecutive levels (child spread / parent).
    pub shrink: f64,
    /// Standard deviation of leaf-cluster point scatter.
    pub leaf_sigma: f64,
    /// Ambient isotropic noise level on all D coordinates.
    pub noise: f64,
    /// RNG seed.
    pub seed: u64,
}

impl SynthSpec {
    /// SIFT-like surrogate: D=128, 3 levels × 8 branches (up to 512 leaf
    /// clusters), intrinsic dimension 8.
    pub fn sift_like(n: usize, seed: u64) -> Self {
        SynthSpec {
            n,
            d: 128,
            intrinsic: 8,
            depth: 3,
            branching: 8,
            shrink: 0.35,
            leaf_sigma: 0.05,
            noise: 0.02,
            seed,
        }
    }

    /// GIST-like surrogate: D=960, denser neighborhoods (paper uses k=90),
    /// smoother global structure: 2 levels × 12 branches, intrinsic dim 6.
    pub fn gist_like(n: usize, seed: u64) -> Self {
        SynthSpec {
            n,
            d: 960,
            intrinsic: 6,
            depth: 2,
            branching: 12,
            shrink: 0.3,
            leaf_sigma: 0.08,
            noise: 0.02,
            seed,
        }
    }

    /// Small low-dimensional mixture for unit tests and the mean-shift
    /// example: `k` well-separated isotropic blobs in R^d.
    pub fn blobs(n: usize, d: usize, k: usize, seed: u64) -> Self {
        SynthSpec {
            n,
            d,
            intrinsic: d,
            depth: 1,
            branching: k,
            shrink: 1.0,
            leaf_sigma: 0.06,
            noise: 0.0,
            seed,
        }
    }

    /// Generate the dataset.  Labels record the leaf-cluster id.
    pub fn generate(&self) -> Dataset {
        assert!(self.intrinsic <= self.d);
        let mut rng = Rng::new(self.seed);

        // 1. Build leaf-cluster centers by recursive offsets in R^intrinsic.
        let mut centers: Vec<Vec<f64>> = vec![vec![0.0; self.intrinsic]];
        let mut spread = 1.0f64;
        for _ in 0..self.depth {
            let mut next = Vec::with_capacity(centers.len() * self.branching);
            for c in &centers {
                for _ in 0..self.branching {
                    let child: Vec<f64> = c
                        .iter()
                        .map(|&v| v + spread * rng.normal())
                        .collect();
                    next.push(child);
                }
            }
            centers = next;
            spread *= self.shrink;
        }
        let k = centers.len();

        // 2. Heavy-tailed cluster occupancy: p(c) ∝ 1/(rank+1).
        let mut weights: Vec<f64> = (0..k).map(|i| 1.0 / (i + 1) as f64).collect();
        rng.shuffle(&mut weights);
        let total: f64 = weights.iter().sum();
        let mut cum = Vec::with_capacity(k);
        let mut acc = 0.0;
        for w in &weights {
            acc += w / total;
            cum.push(acc);
        }

        // 3. Random orthonormal-ish embedding R^intrinsic -> R^d with a
        // decaying spectrum: columns are random unit vectors scaled by
        // 1/sqrt(axis rank+1); Gram–Schmidt keeps them near-orthogonal.
        let basis = random_decaying_basis(&mut rng, self.d, self.intrinsic);

        // 4. Sample points.
        let mut xs = vec![0.0f32; self.n * self.d];
        let mut labels = vec![0u32; self.n];
        for i in 0..self.n {
            let u = rng.f64();
            let c = cum.partition_point(|&x| x < u).min(k - 1);
            labels[i] = c as u32;
            // intrinsic coordinates: center + leaf scatter
            let zi: Vec<f64> = centers[c]
                .iter()
                .map(|&v| v + self.leaf_sigma * rng.normal())
                .collect();
            let row = &mut xs[i * self.d..(i + 1) * self.d];
            for (a, brow) in basis.iter().enumerate() {
                // x = B z + noise; basis stored column-major: basis[a] is
                // the a-th column (length d).
                let za = zi[a];
                for (j, &b) in brow.iter().enumerate() {
                    row[j] += (za * b) as f32;
                }
            }
            if self.noise > 0.0 {
                for v in row.iter_mut() {
                    *v += (self.noise * rng.normal()) as f32;
                }
            }
        }
        let mut ds = Dataset::new(self.n, self.d, xs);
        ds.labels = Some(labels);
        ds
    }
}

/// `k` near-orthonormal columns in R^d with decaying scale 1/sqrt(rank+1).
fn random_decaying_basis(rng: &mut Rng, d: usize, k: usize) -> Vec<Vec<f64>> {
    let mut cols: Vec<Vec<f64>> = Vec::with_capacity(k);
    for a in 0..k {
        let mut v: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
        // Gram–Schmidt against previous columns.
        for prev in &cols {
            let pn: f64 = prev.iter().map(|x| x * x).sum();
            if pn > 0.0 {
                let dot: f64 = v.iter().zip(prev).map(|(a, b)| a * b).sum();
                for (vi, pi) in v.iter_mut().zip(prev) {
                    *vi -= dot / pn * pi;
                }
            }
        }
        let norm: f64 = v.iter().map(|x| x * x).sum::<f64>().sqrt();
        let scale = 1.0 / (norm * ((a + 1) as f64).sqrt());
        for vi in v.iter_mut() {
            *vi *= scale;
        }
        cols.push(v);
    }
    cols
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_and_labels() {
        let ds = SynthSpec::sift_like(500, 1).generate();
        assert_eq!(ds.n(), 500);
        assert_eq!(ds.d(), 128);
        let labels = ds.labels.as_ref().unwrap();
        assert_eq!(labels.len(), 500);
        assert!(labels.iter().all(|&l| (l as usize) < 512));
    }

    #[test]
    fn deterministic_per_seed() {
        let a = SynthSpec::blobs(200, 4, 5, 7).generate();
        let b = SynthSpec::blobs(200, 4, 5, 7).generate();
        assert_eq!(a, b);
        let c = SynthSpec::blobs(200, 4, 5, 8).generate();
        assert_ne!(a, c);
    }

    #[test]
    fn blobs_are_separated() {
        // Same-label pairs must be much closer than different-label pairs on
        // average — the generator's basic sanity.
        let ds = SynthSpec::blobs(300, 3, 4, 42).generate();
        let labels = ds.labels.clone().unwrap();
        let (mut same, mut diff, mut ns, mut nd) = (0.0, 0.0, 0, 0);
        for i in 0..ds.n() {
            for j in (i + 1)..ds.n().min(i + 50) {
                let d2 = ds.sqdist(i, j) as f64;
                if labels[i] == labels[j] {
                    same += d2;
                    ns += 1;
                } else {
                    diff += d2;
                    nd += 1;
                }
            }
        }
        assert!(ns > 0 && nd > 0);
        assert!(
            same / ns as f64 * 5.0 < diff / nd as f64,
            "clusters not separated: same={} diff={}",
            same / ns as f64,
            diff / nd as f64
        );
    }

    #[test]
    fn cluster_structure_survives_in_top_axes() {
        // Variance along the planted principal axes must dominate the
        // ambient noise: the top-intrinsic PCA energy fraction should be
        // large. Cheap proxy: total variance vs noise*noise*d.
        let spec = SynthSpec::sift_like(800, 3);
        let ds = spec.generate();
        let mean = ds.mean();
        let mut total = 0.0f64;
        for i in 0..ds.n() {
            for (k, &v) in ds.row(i).iter().enumerate() {
                let t = (v - mean[k]) as f64;
                total += t * t;
            }
        }
        total /= ds.n() as f64;
        let noise_energy = spec.noise * spec.noise * spec.d as f64;
        assert!(
            total > 4.0 * noise_energy,
            "structure energy too low: {total} vs noise {noise_energy}"
        );
    }

    #[test]
    fn heavy_tail_occupancy() {
        let ds = SynthSpec::sift_like(4000, 9).generate();
        let labels = ds.labels.unwrap();
        let mut counts = std::collections::HashMap::new();
        for l in labels {
            *counts.entry(l).or_insert(0usize) += 1;
        }
        let mut sizes: Vec<usize> = counts.values().copied().collect();
        sizes.sort_unstable_by(|a, b| b.cmp(a));
        // Largest cluster should dominate the median occupied cluster.
        let median = sizes[sizes.len() / 2];
        assert!(sizes[0] >= 4 * median.max(1), "not heavy-tailed: {sizes:?}");
    }
}
