//! End-to-end driver (DESIGN.md §4): the full three-layer system on a real
//! small workload.
//!
//! Pipeline: synthetic 10-class SIFT-like corpus (N=5000, D=128) → PCA →
//! exact kNN → perplexity-calibrated joint P → dual-tree hierarchical
//! reorder → multi-level CSB → 500 t-SNE iterations where the attractive
//! force runs through the hybrid coordinator (Rust workers for sparse
//! blocklets + **PJRT-executed AOT Pallas block programs** for dense
//! cluster pairs) → KL-divergence curve + nearest-centroid class purity.
//!
//! ```bash
//! make artifacts && cargo run --release --example tsne_end_to_end
//! ```
//! Pass `--no-pjrt` to compare against the pure-Rust path.

use nni::apps::tsne::{self, TsneConfig};
use nni::data::synth::SynthSpec;
use nni::runtime::ArtifactRegistry;

fn main() {
    let no_pjrt = std::env::args().any(|a| a == "--no-pjrt");
    let quick = std::env::args().any(|a| a == "--quick");

    // 10-class corpus: depth-1 hierarchy with 10 branches → 10 leaf
    // clusters at D=128 with ambient noise.
    let mut spec = SynthSpec::sift_like(if quick { 1200 } else { 5000 }, 4242);
    spec.depth = 1;
    spec.branching = 10;
    spec.leaf_sigma = 0.08;
    let data = spec.generate();
    println!(
        "corpus: {} points, d={}, {} classes",
        data.n(),
        data.d(),
        data.labels.as_ref().unwrap().iter().max().unwrap() + 1
    );

    let registry = if no_pjrt {
        None
    } else {
        match ArtifactRegistry::open_default() {
            Ok(r) => {
                println!("pjrt: {} ({} artifacts)", r.runtime().platform(), r.variants.len());
                Some(r)
            }
            Err(e) => {
                println!("pjrt unavailable ({e:#}); running pure-Rust");
                None
            }
        }
    };

    let cfg = TsneConfig {
        d: 2,
        perplexity: 30.0,
        k: 90.min(data.n() - 1),
        iters: if quick { 150 } else { 500 },
        exaggeration_iters: if quick { 50 } else { 100 },
        threads: 0,
        seed: 7,
        leaf_cap: 256,
        use_pjrt: registry.is_some(),
        ..Default::default()
    };

    let t0 = std::time::Instant::now();
    let res = tsne::run(&data, &cfg, registry);
    let total = t0.elapsed().as_secs_f64();

    println!("\nKL curve:");
    for e in &res.log {
        println!("  iter {:>4}  KL {:.4}  |grad| {:.3e}  t {:.1}s", e.iter, e.kl, e.grad_norm, e.seconds);
    }
    println!("\ncoordinator: {}", res.metrics_summary);
    println!("total wall time: {total:.1}s  ({:.1} ms/iter)", total * 1e3 / cfg.iters as f64);

    // Quality: nearest-class-centroid agreement in the embedding.
    let e = &res.embedding;
    let labels = e.labels.as_ref().unwrap();
    let nclass = (*labels.iter().max().unwrap() + 1) as usize;
    let mut centroids = vec![[0.0f64; 2]; nclass];
    let mut counts = vec![0usize; nclass];
    for i in 0..e.n() {
        let c = labels[i] as usize;
        centroids[c][0] += e.row(i)[0] as f64;
        centroids[c][1] += e.row(i)[1] as f64;
        counts[c] += 1;
    }
    for (c, cnt) in centroids.iter_mut().zip(&counts) {
        c[0] /= (*cnt).max(1) as f64;
        c[1] /= (*cnt).max(1) as f64;
    }
    let mut correct = 0usize;
    for i in 0..e.n() {
        let (x, y) = (e.row(i)[0] as f64, e.row(i)[1] as f64);
        let mut best = (f64::INFINITY, 0usize);
        for (c, cen) in centroids.iter().enumerate() {
            let d2 = (x - cen[0]).powi(2) + (y - cen[1]).powi(2);
            if d2 < best.0 {
                best = (d2, c);
            }
        }
        if best.1 == labels[i] as usize {
            correct += 1;
        }
    }
    let purity = correct as f64 / e.n() as f64;
    println!("nearest-centroid purity: {purity:.3}");

    // KL must decrease post-exaggeration; purity must beat chance well.
    let post: Vec<_> = res.log.iter().filter(|l| l.iter >= cfg.exaggeration_iters).collect();
    assert!(post.len() >= 2 && post.last().unwrap().kl <= post[0].kl + 1e-9, "KL did not decrease");
    assert!(purity > 2.0 / nclass as f64, "purity {purity} barely above chance");
    println!("END-TO-END OK");
}
