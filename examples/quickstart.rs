//! Quickstart: the whole public API in ~60 lines.
//!
//! Synthesizes a SIFT-like dataset, builds its kNN interaction matrix,
//! reorders it with the paper's dual-tree hierarchical ordering, compares
//! the γ-score against the scattered baseline, and runs the multi-level
//! SpMV.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use nni::csb::hier::HierCsb;
use nni::data::synth::SynthSpec;
use nni::knn::exact::knn_graph;
use nni::order::{OrderingKind, Pipeline};
use nni::profile::gamma::gamma_fast;
use nni::sparse::csr::Csr;
use nni::spmv;

fn main() {
    // 1. Data: 2048 points in R^128 with multi-scale cluster structure.
    let data = SynthSpec::sift_like(2048, 42).generate();
    println!("dataset: {} points, d={}", data.n(), data.d());

    // 2. Interaction profile: symmetrized 16-NN graph (Eq. 1).
    let g = knn_graph(&data, 16, 0);
    let a = Csr::from_knn(&g, data.n()).symmetrized();
    println!("interaction matrix: {} nonzeros", a.nnz());

    // 3. Orderings: scattered baseline vs the paper's 3-D dual tree.
    let scattered = Pipeline::new(OrderingKind::Scattered).run(&data, &a);
    let dualtree = Pipeline::dual_tree(3).run(&data, &a);

    // 4. Profile quality (γ-score, Eq. 4): higher = better locality.
    let sigma = 8.0;
    println!(
        "gamma: scattered = {:.2}, dual-tree = {:.2}",
        gamma_fast(&scattered.reordered, sigma),
        gamma_fast(&dualtree.reordered, sigma),
    );

    // 5. Multi-level storage + SpMV on the reordered matrix.
    let tree = dualtree.tree.as_ref().unwrap();
    // block cap 512 at this toy scale (EXPERIMENTS.md §Perf discusses the
    // capacity trade-off; 2048 is the sweet spot at n >= 8192)
    let csb = HierCsb::build(&dualtree.reordered, tree, tree, 512);
    println!("csb: {}", csb.describe());

    let x = vec![1.0f32; data.n()];
    let mut y = vec![0.0f32; data.n()];
    let t_csr = nni::util::timer::bench_default(|| {
        spmv::csr::spmv_seq(&scattered.reordered, &x, &mut y)
    });
    let t_ml = nni::util::timer::bench_default(|| {
        spmv::multilevel::spmv_ml_seq(&csb, &x, &mut y)
    });
    println!(
        "spmv: scattered-CSR {:.3} ms  vs  dual-tree multilevel {:.3} ms  ({:.2}x)",
        t_csr.robust_min_s * 1e3,
        t_ml.robust_min_s * 1e3,
        t_csr.robust_min_s / t_ml.robust_min_s
    );
    println!(
        "(gamma is the machine-independent locality signal; time ratios depend\n \
         on the cache hierarchy — see EXPERIMENTS.md §Testbed and fig3)"
    );
}
