//! Ordering explorer: reproduces the *visual* comparison of Fig. 2 —
//! the sparsity profile of the same interaction matrix under all six
//! orderings — as PGM rasters plus γ/β̂/bandwidth stats.
//!
//! ```bash
//! cargo run --release --example ordering_explorer -- [n] [sift|gist]
//! # outputs bench_out/profile_<ordering>.pgm + a stats table
//! ```

use nni::bench::{out_dir, Workload};
use nni::order::{OrderingKind, Pipeline};
use nni::profile::{beta, gamma, render};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(4096);
    let wl = match args.get(1).map(String::as_str) {
        Some("gist") => Workload::Gist,
        _ => Workload::Sift,
    };
    println!("workload: {} n={n} k={}", wl.name(), wl.k());
    let (ds, a) = wl.make(n, 77, 0);
    let sigma = wl.k() as f64 / 2.0;
    let g = 256.min(n);

    println!(
        "{:>10} {:>10} {:>10} {:>12} {:>10}",
        "ordering", "gamma", "beta-hat", "bandwidth", "raster"
    );
    for kind in OrderingKind::table1_set() {
        let r = Pipeline::new(kind.clone()).run(&ds, &a);
        let gm = gamma::gamma_fast(&r.reordered, sigma);
        let bt = beta::beta_estimate(&r.reordered);
        let grid = render::density_grid(&r.reordered, g);
        let fname = format!(
            "profile_{}.pgm",
            kind.label().replace(' ', "_").to_lowercase()
        );
        let path = out_dir().join(&fname);
        render::write_pgm(&grid, g, &path).expect("write pgm");
        println!(
            "{:>10} {:>10.2} {:>10.5} {:>12} {:>10}",
            kind.label(),
            gm,
            bt.beta,
            r.reordered.bandwidth(),
            fname
        );
    }
    println!("\nrasters in {}/ — dark pixels = dense regions", out_dir().display());
}
