//! Mean-shift case study (§3.2): non-parametric mode finding where the
//! interaction profile *changes across iterations* — the target means
//! migrate, and the coordinator refreshes the kNN profile + target tree at
//! a lower cadence than the value updates.
//!
//! ```bash
//! cargo run --release --example meanshift_modes
//! ```

use nni::apps::meanshift::{self, MeanShiftConfig};
use nni::data::synth::SynthSpec;

fn main() {
    // 6 planted modes in R^3, heavy ambient mixing.
    let data = SynthSpec::blobs(4000, 3, 6, 2024).generate();
    println!("dataset: {} points, d={}", data.n(), data.d());

    for refresh in [1usize, 5, 10] {
        let cfg = MeanShiftConfig {
            bandwidth: 0.22,
            k: 48,
            max_iters: 60,
            refresh_every: refresh,
            threads: 0,
            ..Default::default()
        };
        let t0 = std::time::Instant::now();
        let res = meanshift::run(&data, &cfg);
        let dt = t0.elapsed().as_secs_f64();

        // purity vs planted labels
        let labels = data.labels.as_ref().unwrap();
        let mut votes: std::collections::HashMap<(usize, u32), usize> = Default::default();
        for i in 0..data.n() {
            *votes.entry((res.assignment[i], labels[i])).or_default() += 1;
        }
        let mut per_mode_best: std::collections::HashMap<usize, usize> = Default::default();
        for (&(m, _), &c) in &votes {
            let e = per_mode_best.entry(m).or_default();
            *e = (*e).max(c);
        }
        let purity: f64 =
            per_mode_best.values().sum::<usize>() as f64 / data.n() as f64;

        println!(
            "refresh_every={refresh:>2}: {} modes in {} iters, purity {:.3}, {:.2}s",
            res.modes.len(),
            res.iterations,
            purity,
            dt
        );
    }
    println!("(the paper's point: the clustering refresh cadence trades a little\n\
              accuracy in the profile for large savings in re-partitioning work)");
}
